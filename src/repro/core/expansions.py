"""KernelExpansion — the pluggable kernel-decomposition layer.

The paper's formulation is "GP with a *decomposed kernel*": everything
downstream of the feature map (the Woodbury M x M solve, the streaming
moment accumulation, the distributed schedules, the bank) only needs

    k(x, x') ~= sum_m lambda_m phi_m(x) phi_m(x')

for SOME low-rank family {(lambda_m, phi_m)}.  This module makes that
family a first-class, registered object instead of a hard-wired Hermite
eigen-expansion.  A :class:`KernelExpansion` supplies:

* static structure — ``indices(spec)`` (the (M, w) integer table baked into
  ``FAGPState.idx``; its row count IS the feature count M) and
  ``validate(spec)``;
* weights — ``log_eigenvalues(idx, spec)``, consumed by the scaled solve
  ``B = I + D G D / sigma^2`` exactly as before (log space, so families
  with geometric decay and families with flat weights share one code path);
* a jnp feature map — ``features(X, idx, spec)`` -> (N, M), differentiable
  through the spec's data leaves (NLML hyperparameter learning);
* a tile-level feature generator for the Pallas kernels — a module-level
  ``tile_fn(xt, consts, table, *, p, n_max)`` plus the ``tile_consts`` /
  ``tile_table`` arrays it consumes — usable both for standalone feature
  construction (``kernels.ops.expansion_phi``) and inside the streaming
  fused-fit kernel (``kernels.phi_gram``), so every expansion fits without
  materializing the N x M Phi;
* an exact-kernel oracle — ``exact_kernel(Xa, Xb, spec)`` — pinning
  ``Phi diag(lam) Phi^T -> k`` in the property tests.

Registered instances:

* ``hermite``      — the paper's Hermite-Mercer eigen-expansion of the SE
  kernel (Eqs. 13-20), extracted from what used to be hard-wired across
  ``GPSpec`` / ``mercer`` / the kernels; truncation error decays
  geometrically with ``spec.n``.
* ``rff_se``       — random Fourier features of the same SE kernel:
  M = 2R paired cos/sin columns over R spectral frequencies
  w_r = sqrt(2) * eps (.) omega_r with base draws omega_r ~ N(0, I)
  carried as a data leaf on the spec (``GPSpec.omega``); Monte-Carlo error
  O(1/sqrt(R)).
* ``rff_matern52`` — random Fourier features of the ARD Matern-5/2 kernel
  (lengthscale convention matched to the SE eps — see
  ``mercer.k_matern52_ard``): base draws are multivariate-t with
  2*nu = 5 degrees of freedom, omega_r = z_r * sqrt(5 / g_r), g_r ~ chi^2_5.

The lengthscale scaling sqrt(2)*eps is applied INSIDE ``features`` /
``tile_table`` (the stored ``omega`` is eps-free), so NLML gradients flow
through the RFF lengthscales exactly as they do through the Mercer
eigenvalues.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import mercer

__all__ = [
    "KernelExpansion",
    "HermiteMercerExpansion",
    "RandomFourierExpansion",
    "register_expansion",
    "get_expansion",
    "available_expansions",
]

# the Pallas Hermite kernels unroll the scaled recurrence n_max times in the
# kernel body; past this depth the unrolled program is impractical (and the
# eigenvalues have underflown f32 for ~25 columns already)
_PALLAS_MAX_N = 64


class KernelExpansion:
    """Protocol (duck-typed base) for a pluggable kernel decomposition.

    ``spec`` throughout is a :class:`repro.core.fagp.GPSpec`; expansions
    read its static metadata (n, index_set, degree, expansion) and its data
    leaves (eps, rho, noise, omega) but never import ``fagp`` (the spec is
    duck-typed to keep the layering acyclic).
    """

    name: str = "?"

    # -- static structure ---------------------------------------------------

    def validate(self, spec) -> None:
        """Raise ValueError when the spec is malformed for this expansion."""

    def indices(self, spec, p: Optional[int] = None) -> np.ndarray:
        """The (M, w) static integer table identifying the M features."""
        raise NotImplementedError

    def draw_spec_data(self, p: int, num_features: int, seed: int):
        """Random data leaves (``GPSpec.omega``) the expansion needs, or
        None for deterministic expansions."""
        return None

    # -- weights ------------------------------------------------------------

    def log_eigenvalues(self, idx: jax.Array, spec) -> jax.Array:
        """(M,) log weights lambda_m of the decomposition."""
        raise NotImplementedError

    # -- feature maps -------------------------------------------------------

    def features(self, X: jax.Array, idx: jax.Array, spec) -> jax.Array:
        """(N, M) feature matrix, pure jnp (differentiable reference path)."""
        raise NotImplementedError

    def exact_kernel(self, Xa: jax.Array, Xb: jax.Array, spec) -> jax.Array:
        """The kernel this expansion decomposes — the parity oracle."""
        raise NotImplementedError

    # -- Pallas tile contract (see kernels/hermite_phi.py) ------------------

    def pallas_supports(self, spec) -> Optional[str]:
        """None when the Pallas tile path can run this spec, else a reason
        string — surfaced by the backend registry as the structured
        :class:`~repro.core.approximation.UnsupportedError` with
        ``layer="backend"`` (e.g. the Hermite n > 64 recurrence limit)."""
        return None

    def pallas_prepare(self, idx_np: np.ndarray, spec):
        """Static auxiliary for ``tile_table`` (memoized per index set)."""
        return None

    def tile_fn(self):
        """The module-level tile builder (stable identity for jit caches)."""
        raise NotImplementedError

    def tile_consts(self, spec) -> jax.Array:
        """Small global table replicated to every tile."""
        raise NotImplementedError

    def tile_table(self, aux, spec) -> jax.Array:
        """(K, M) per-column table blocked along the feature axis."""
        raise NotImplementedError


class HermiteMercerExpansion(KernelExpansion):
    """The paper's expansion: tensor-product Hermite eigenfunctions of the
    ARD SE kernel w.r.t. a Gaussian measure (Eqs. 13-20), truncated by a
    multi-index set.  All math delegates to ``core.mercer`` — the single
    home of the eigensystem and of the scaled Hermite recurrence."""

    name = "hermite"

    def validate(self, spec) -> None:
        if spec.n < 1:
            raise ValueError(f"hermite expansion needs n >= 1, got {spec.n}")
        if spec.index_set not in ("full", "total_degree", "hyperbolic_cross"):
            raise ValueError(f"unknown index set {spec.index_set!r}")

    def indices(self, spec, p: Optional[int] = None) -> np.ndarray:
        return mercer.make_index_set(
            spec.index_set, spec.n, p or spec.p, spec.degree
        )

    def log_eigenvalues(self, idx, spec):
        return mercer.log_eigenvalues_nd(idx, spec.params)

    def features(self, X, idx, spec):
        return mercer.phi_nd(X, idx, spec.params, spec.n)

    def exact_kernel(self, Xa, Xb, spec):
        return mercer.k_se_ard(Xa, Xb, spec.eps)

    def pallas_supports(self, spec) -> Optional[str]:
        if spec.n > _PALLAS_MAX_N:
            return (
                f"n={spec.n} exceeds the unrolled Hermite recurrence depth "
                f"the kernels are built for (max {_PALLAS_MAX_N}); use "
                f"backend='jnp'"
            )
        return None

    def pallas_prepare(self, idx_np, spec):
        from repro.kernels import ref as kref

        return jnp.asarray(kref.one_hot_selection(idx_np, spec.n))

    def tile_fn(self):
        from repro.kernels.hermite_phi import phi_tile

        return phi_tile

    def tile_consts(self, spec):
        from repro.kernels import ref as kref

        return kref.phi_consts(spec.eps, spec.rho)

    def tile_table(self, aux, spec):
        return aux  # the static one-hot selection from pallas_prepare


class RandomFourierExpansion(KernelExpansion):
    """Random Fourier features of a stationary kernel (Rahimi-Recht):
    M = 2R paired cos/sin columns, flat weights lambda_m = 1/R, spectral
    base draws stored eps-free in ``GPSpec.omega`` and scaled by
    sqrt(2) * eps inside the feature map (differentiable lengthscales).

    ``kernel`` selects the spectral measure and the exact-kernel oracle:
    'se' (Gaussian frequencies) or 'matern52' (multivariate-t, 5 dof).
    """

    def __init__(self, kernel: str):
        if kernel not in ("se", "matern52"):
            raise ValueError(f"unknown RFF kernel family {kernel!r}")
        self.kernel = kernel
        self.name = f"rff_{kernel}"

    def validate(self, spec) -> None:
        if spec.omega is None:
            raise ValueError(
                f"{self.name} needs spectral base draws on the spec; build "
                f"it with GPSpec.create(..., expansion={self.name!r}, "
                f"num_features=R, seed=...) or GPSpec.create_rff(...)"
            )
        if np.shape(spec.omega) != (np.shape(spec.omega)[0], spec.p):
            raise ValueError(
                f"{self.name}: omega must be (R, p={spec.p}), got "
                f"{np.shape(spec.omega)}"
            )

    def indices(self, spec, p: Optional[int] = None) -> np.ndarray:
        self.validate(spec)
        R = np.shape(spec.omega)[0]
        return np.arange(2 * R, dtype=np.int32).reshape(-1, 1)

    def draw_spec_data(self, p: int, num_features: int, seed: int):
        rng = np.random.default_rng(seed)
        z = rng.standard_normal((num_features, p))
        if self.kernel == "matern52":
            # Matern-nu spectral measure = multivariate-t with 2*nu dof:
            # omega = z * sqrt(2*nu / g), g ~ chi^2_{2*nu}; nu = 5/2
            g = rng.chisquare(5.0, size=(num_features, 1))
            z = z * np.sqrt(5.0 / g)
        return jnp.asarray(z.astype(np.float32))

    def log_eigenvalues(self, idx, spec):
        M = idx.shape[0]
        return jnp.full((M,), -np.log(M / 2.0), jnp.float32)

    def _scaled_freqs(self, spec) -> jax.Array:
        """(R, p) frequencies w_r = sqrt(2) * eps (.) omega_r — the only
        place the lengthscale scaling is applied."""
        return np.sqrt(2.0).astype(np.float32) * spec.eps[None, :] * spec.omega

    def features(self, X, idx, spec):
        W = self._scaled_freqs(spec)                      # (R, p)
        Z = X @ W.T                                       # (N, R)
        return jnp.concatenate([jnp.cos(Z), jnp.sin(Z)], axis=1)

    def exact_kernel(self, Xa, Xb, spec):
        if self.kernel == "se":
            return mercer.k_se_ard(Xa, Xb, spec.eps)
        return mercer.k_matern52_ard(Xa, Xb, spec.eps)

    def pallas_supports(self, spec) -> Optional[str]:
        return None

    def pallas_prepare(self, idx_np, spec):
        return None  # the whole table is data (eps-scaled), built per call

    def tile_fn(self):
        from repro.kernels.rff_phi import rff_tile

        return rff_tile

    def tile_consts(self, spec):
        from repro.kernels.rff_phi import rff_consts_placeholder

        return rff_consts_placeholder()

    def tile_table(self, aux, spec):
        Wt = self._scaled_freqs(spec).T                   # (p, R)
        R = Wt.shape[1]
        phase = jnp.concatenate([
            jnp.zeros((1, R), jnp.float32),
            jnp.full((1, R), -0.5 * np.pi, jnp.float32),
        ], axis=1)                                        # (1, 2R)
        return jnp.concatenate(
            [jnp.concatenate([Wt, Wt], axis=1), phase], axis=0
        )                                                 # (p + 1, 2R)


_EXPANSIONS: dict = {}


def register_expansion(expansion: KernelExpansion) -> None:
    _EXPANSIONS[expansion.name] = expansion


def get_expansion(name: str) -> KernelExpansion:
    try:
        return _EXPANSIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel expansion {name!r}; registered: "
            f"{available_expansions()}"
        ) from None


def available_expansions() -> list:
    return sorted(_EXPANSIONS)


register_expansion(HermiteMercerExpansion())
register_expansion(RandomFourierExpansion("se"))
register_expansion(RandomFourierExpansion("matern52"))
