"""Distributed FAGP over the (pod, data, model) production mesh.

The paper parallelizes the FAGP posterior on ONE GPU with cuBLAS GEMMs.
At pod scale the data no longer fits one device, so the algorithm becomes:

  * X, y row-sharded over (pod, data) — each chip owns N/dp rows;
  * Phi built block-streamed (never materialized for the full N);
  * the two sufficient statistics G = Phi^T Phi (M x M) and b = Phi^T y (M)
    are partial-summed locally and combined by ONE all-reduce each —
    communication volume O(M^2), independent of N (the communication-
    optimal schedule for tall-skinny Gram matrices);
  * G/B kept row-sharded over 'model'; the M x M Cholesky solve runs on the
    gathered matrix (M <= ~16k => <1 GB f32, affordable once per fit);
  * prediction: Phi* row-sharded over (pod, data), mean/variance local per
    shard — embarrassingly parallel, zero collectives after the broadcast
    of (chol, u).

Everything is pjit + sharding constraints: the all-reduces appear in the
lowered HLO (verified by the dry-run's collective parse).

The schedules are expansion-generic: the feature map and log weights come
from the spec's registered :class:`~repro.core.expansions.KernelExpansion`,
so an RFF fit shards exactly like a Hermite fit (the RFF spectral draws
``spec.omega`` are replicated alongside eps/rho — they are hyperparameters,
not data).

API (same self-describing session contract as ``core.fagp``):

    state = fit_distributed(X, y, spec, mesh)       # a normal FAGPState
    mu, var = predict_distributed(Xs, state, mesh)  # spec baked in

The returned state is interchangeable with a single-device fit — it feeds
``predict_mean_var``, ``fit_update`` and the ``GP`` facade directly.  The
split ``fit_distributed(X, y, params, cfg, mesh) -> (u, chol, sqrtlam)``
form was deprecated for two releases and now raises TypeError.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel import hints
from . import shardspec
from .expansions import get_expansion
from .fagp import (
    FAGPState,
    GPSpec,
    _assemble_scaled_system,
    _removed,
    _solve_mean_weights,
    get_backend,
)

__all__ = ["fit_distributed", "predict_distributed", "lower_fit", "lower_predict"]


# Shard-local spec rebuild + mesh probes live in core.shardspec so the
# bank-axis sharding (bank.sharded) shares one copy with the v2 schedules.
_spec_local = shardspec.spec_local
_omega_args = shardspec.omega_args


@partial(jax.jit, static_argnames=("nblk", "n_valid"))
def _fit_fn(X, y, spec: GPSpec, idx, nblk: int, n_valid: int | None = None):
    exp = get_expansion(spec.expansion)
    N = X.shape[0]
    M = idx.shape[0]
    sig2 = spec.noise**2
    loglam = exp.log_eigenvalues(idx, spec)

    block = N // nblk
    Xb = hints.constrain(X.reshape(nblk, block, -1), (None, "dp", None))
    yb = hints.constrain(y.reshape(nblk, block), (None, "dp"))

    def step(carry, inp):
        G, b = carry
        i, Xi, yi = inp
        Xi = hints.constrain(Xi, ("dp", None))
        Phi_i = exp.features(Xi, idx, spec)              # rows sharded over dp
        if n_valid is not None and n_valid < N:          # mask padded rows
            mask = ((i * block + jnp.arange(block)) < n_valid).astype(Phi_i.dtype)
            Phi_i = Phi_i * mask[:, None]
            yi = yi * mask
        G = G + hints.constrain(Phi_i.T @ Phi_i, ("model", None))
        b = b + Phi_i.T @ yi
        return (G, b), None

    G0 = hints.constrain(jnp.zeros((M, M), X.dtype), ("model", None))
    (G, b), _ = jax.lax.scan(
        step, (G0, jnp.zeros((M,), X.dtype)), (jnp.arange(nblk), Xb, yb)
    )

    B, sqrtlam = _assemble_scaled_system(G, loglam, sig2)
    B = hints.constrain(B, ("model", None))
    chol = jnp.linalg.cholesky(B)
    u = _solve_mean_weights(chol, sqrtlam, b, sig2)
    return u, chol, sqrtlam, b


@jax.jit
def _predict_fn(Xs, u, chol, sqrtlam, spec: GPSpec, idx):
    exp = get_expansion(spec.expansion)
    Xs = hints.constrain(Xs, ("dp", None))
    Phis = exp.features(Xs, idx, spec)                   # (N*, M) rows over dp
    mu = Phis @ u
    PhisD = Phis * sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(chol, PhisD.T, lower=True)
    var = jnp.sum(V * V, axis=0)
    return hints.constrain(mu, ("dp",)), hints.constrain(var, ("dp",))


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in hints.dp_axes(mesh)]))


def _pick_nblk(N: int, M: int, dp: int = 1) -> tuple[int, int]:
    """(nblk, N_padded): row blocks ~100 MB f32 of Phi per device, with
    N padded so blocks exist and every block divides the dp axis."""
    target_rows = max(dp, int(100e6 / 4 / max(M, 1)) * dp)
    nblk = max(1, N // target_rows)
    nblk = min(nblk, 256)
    quantum = nblk * dp
    N_pad = (N + quantum - 1) // quantum * quantum
    return nblk, N_pad


def _fit_distributed_spec(X, y, spec: GPSpec, mesh) -> FAGPState:
    """The actual distributed fit; returns a self-describing FAGPState
    (Phi/y not stored — they are sharded training data, not serving state)."""
    N, p = X.shape
    idx_np = spec.indices(p)
    idx = jnp.asarray(idx_np)
    if spec.backend != "jnp":
        n_chips = _n_chips(mesh)
        N_pad = (N + n_chips - 1) // n_chips * n_chips
        if N_pad != N:
            X = jnp.pad(X, ((0, N_pad - N), (0, 0)))
            y = jnp.pad(y, (0, N_pad - N))
        aux = get_backend(spec.backend).prepare(idx_np, spec)
        with jax.set_mesh(mesh), hints.activate(mesh):
            f = jax.jit(partial(
                _fit_fn_v2, nblk=16, mesh=mesh,
                n_valid=N if N_pad != N else None,
                backend=spec.backend, aux=aux,
            ))
            u, chol, sqrtlam, b = f(X, y, spec, idx)
    else:
        nblk, N_pad = _pick_nblk(N, idx.shape[0], _dp_size(mesh))
        if N_pad != N:
            X = jnp.pad(X, ((0, N_pad - N), (0, 0)))
            y = jnp.pad(y, (0, N_pad - N))
        with jax.set_mesh(mesh), hints.activate(mesh):
            dp = hints.dp_axes(mesh)
            f = jax.jit(
                partial(_fit_fn, nblk=nblk,
                        n_valid=N if N_pad != N else None),
                in_shardings=(
                    NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp)),
                    None, None,
                ),
            )
            u, chol, sqrtlam, b = f(X, y, spec, idx)
    loglam = get_expansion(spec.expansion).log_eigenvalues(idx, spec)
    return FAGPState(
        idx=idx, lam=jnp.exp(loglam), sqrtlam=sqrtlam, chol=chol, u=u,
        params=spec.params, Phi=None, y=None, b=b, spec=spec,
    )


def fit_distributed(X, y, spec, *args):
    """Distributed fit returning a self-describing :class:`FAGPState`.

    ``fit_distributed(X, y, spec, mesh)``.  ``spec.backend`` selects the
    per-shard engine via the core.fagp registry: 'jnp' runs the v1 pjit
    schedule, anything else runs the v2 shard_map schedule with that
    backend's streaming moments kernel per shard (e.g. 'pallas' = fused
    phi+gram, Phi never materialized — for any registered expansion).

    The split ``fit_distributed(X, y, params, cfg, mesh)`` form was removed.
    """
    if not isinstance(spec, GPSpec):
        _removed(
            "fit_distributed(X, y, params, cfg, mesh)",
            "merge them with GPSpec.from_parts(params, cfg) and call "
            "fit_distributed(X, y, spec, mesh), which returns an FAGPState",
        )
    if len(args) != 1:
        raise TypeError("fit_distributed(X, y, spec, mesh): expected mesh")
    return _fit_distributed_spec(X, y, spec, args[0])


def predict_distributed(Xs, state, *args):
    """Shard-local posterior mean/variance over the mesh.

    ``predict_distributed(Xs, state, mesh)`` with the self-describing state
    returned by :func:`fit_distributed` (or a single-device ``fit`` — the
    schedule only needs u/chol/sqrtlam).

    The ``predict_distributed(Xs, (u, chol, sqrtlam), params, cfg, mesh)``
    form was removed.
    """
    if len(args) != 1:
        _removed(
            "predict_distributed(Xs, state_tuple, params, cfg, mesh)",
            "fit with fit_distributed(X, y, spec, mesh) and call "
            "predict_distributed(Xs, state, mesh)",
        )
    mesh = args[0]
    if not isinstance(state, FAGPState) or state.spec is None:
        raise ValueError(
            "predict_distributed(Xs, state, mesh) needs a self-describing "
            "FAGPState (from fit_distributed or fit)"
        )
    spec = state.spec
    u, chol, sqrtlam = state.u, state.chol, state.sqrtlam
    idx = state.idx
    N = Xs.shape[0]
    dpn = _dp_size(mesh)
    N_pad = (N + dpn - 1) // dpn * dpn
    if N_pad != N:
        Xs = jnp.pad(Xs, ((0, N_pad - N), (0, 0)))
    with jax.set_mesh(mesh), hints.activate(mesh):
        dp = hints.dp_axes(mesh)
        f = jax.jit(
            _predict_fn,
            in_shardings=(
                NamedSharding(mesh, P(dp, None)), None, None, None, None, None,
            ),
        )
        mu, var = f(Xs, u, chol, sqrtlam, spec, idx)
    return mu[:N], var[:N]


# ---------------------------------------------------------------------------
# v2 schedule (§Perf iteration 1): explicit shard_map
#
# Baseline (v1) constrained G to ("model", None) every scan step, which made
# XLA all-gather each Phi block over dp and reshard the Gram each iteration:
# 439 GB of wire per device for fit_8m (collective term 8.78 s) and 16-32x
# redundant compute.  v2 shards rows over EVERY mesh axis, streams the local
# Gram in-shard, and reduces ONCE:  wire = 2 x |G| = 1.7 GB -> ~34 ms, and
# compute = 2NM^2 / n_chips exactly.  Prediction is fully local per shard
# (u, Binv replicated): zero per-row collectives.
# ---------------------------------------------------------------------------


def _fit_fn_v2(X, y, spec: GPSpec, idx, nblk: int, mesh,
               n_valid: int | None = None, backend: str = "jnp", aux=None):
    exp = get_expansion(spec.expansion)
    N = X.shape[0]
    M = idx.shape[0]
    sig2 = spec.noise**2
    loglam = exp.log_eigenvalues(idx, spec)
    axes = tuple(mesh.axis_names)
    n_chips = int(np.prod([mesh.shape[a] for a in axes]))
    N_l = N // n_chips
    block = max(1, N_l // nblk)

    def local(Xl, yl, eps, rho, *omega_t):
        lo = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            lo = lo * mesh.shape[a] + jax.lax.axis_index(a)
        row0 = lo * N_l
        s_loc = _spec_local(spec, eps, rho, omega_t[0] if omega_t else None)

        if backend != "jnp":
            # registry path: the whole shard's moments in ONE streaming
            # fused-kernel call (feature tiles generated in VMEM by the
            # expansion's tile builder, never in HBM)
            mask = None
            if n_valid is not None and n_valid < N:
                mask = ((row0 + jnp.arange(N_l)) < n_valid).astype(Xl.dtype)
            G_l, b_l = get_backend(backend).moments(
                Xl, yl, s_loc, idx, aux, block, mask
            )
        else:
            def step(carry, inp):
                G, b = carry
                i, Xi, yi = inp
                Phi_i = exp.features(Xi, idx, s_loc)
                if n_valid is not None and n_valid < N:
                    mask = ((row0 + i * block + jnp.arange(block)) < n_valid)
                    Phi_i = Phi_i * mask.astype(Phi_i.dtype)[:, None]
                    yi = yi * mask.astype(yi.dtype)
                return (G + Phi_i.T @ Phi_i, b + Phi_i.T @ yi), None

            nb = N_l // block
            (G_l, b_l), _ = jax.lax.scan(
                step,
                (jnp.zeros((M, M), Xl.dtype), jnp.zeros((M,), Xl.dtype)),
                (jnp.arange(nb), Xl.reshape(nb, block, -1), yl.reshape(nb, block)),
            )
        G = jax.lax.psum(G_l, axes)        # THE one collective (M x M)
        b = jax.lax.psum(b_l, axes)
        return G, b

    omega_args = _omega_args(spec)
    G, b = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axes), P(axes), P(), P()) + (P(),) * len(omega_args),
        out_specs=(P(), P()),
        check_vma=False,
    )(X.reshape(N, -1), y, spec.eps, spec.rho, *omega_args)

    B, sqrtlam = _assemble_scaled_system(G, loglam, sig2)
    chol = jnp.linalg.cholesky(B)
    u = _solve_mean_weights(chol, sqrtlam, b, sig2)
    return u, chol, sqrtlam, b


def _predict_fn_v2(Xs, u, chol, sqrtlam, spec: GPSpec, idx, mesh):
    """Fully local per row: Binv replicated, var = rowsum((Phi D Binv)*(Phi D))."""
    exp = get_expansion(spec.expansion)
    M = idx.shape[0]
    Binv = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(M, dtype=chol.dtype))
    axes = tuple(mesh.axis_names)

    def local(Xl, u_, Binv_, sqrtlam_, eps, rho, *omega_t):
        s_loc = _spec_local(spec, eps, rho, omega_t[0] if omega_t else None)
        Phis = exp.features(Xl, idx, s_loc)
        mu = Phis @ u_
        PD = Phis * sqrtlam_[None, :]
        var = jnp.sum((PD @ Binv_) * PD, axis=1)
        return mu, var

    omega_args = _omega_args(spec)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axes), P(), P(), P(), P(), P()) + (P(),) * len(omega_args),
        out_specs=(P(axes), P(axes)),
        check_vma=False,
    )(Xs, u, Binv, sqrtlam, spec.eps, spec.rho, *omega_args)


# ---------------------------------------------------------------------------
# Dry-run lowering (ShapeDtypeStructs only, no allocation)
# ---------------------------------------------------------------------------


def _abstract_spec(cfg, p: int) -> GPSpec:
    """Abstract (ShapeDtypeStruct-leaved) hermite GPSpec for a workload's
    FAGPConfig — the dry-run never allocates hyperparameters."""
    f32 = jnp.float32
    return GPSpec(
        eps=jax.ShapeDtypeStruct((p,), f32),
        rho=jax.ShapeDtypeStruct((p,), f32),
        noise=jax.ShapeDtypeStruct((), f32),
        n=cfg.n, index_set=cfg.index_set, degree=cfg.degree,
        block_rows=cfg.block_rows, store_train=cfg.store_train,
        backend=cfg.backend,
    )


_n_chips = shardspec.mesh_size


def lower_fit(wl, mesh, *, schedule: str = "v2"):
    idx_np = wl.cfg.indices(wl.p)
    idx = jnp.asarray(idx_np)
    spec_av = _abstract_spec(wl.cfg, wl.p)
    if schedule == "v2":
        quantum = _n_chips(mesh) * 16
        N_pad = (wl.N + quantum - 1) // quantum * quantum
        X = jax.ShapeDtypeStruct((N_pad, wl.p), jnp.float32)
        y = jax.ShapeDtypeStruct((N_pad,), jnp.float32)
        backend = wl.cfg.backend
        aux = (get_backend(backend).prepare(idx_np, spec_av)
               if backend != "jnp" else None)
        return jax.jit(
            partial(_fit_fn_v2, nblk=16, mesh=mesh,
                    n_valid=wl.N if N_pad != wl.N else None,
                    backend=backend, aux=aux),
        ).lower(X, y, spec_av, idx)
    nblk, N_pad = _pick_nblk(wl.N, idx_np.shape[0], _dp_size(mesh))
    X = jax.ShapeDtypeStruct((N_pad, wl.p), jnp.float32)
    y = jax.ShapeDtypeStruct((N_pad,), jnp.float32)
    dp = hints.dp_axes(mesh)
    return jax.jit(
        partial(_fit_fn, nblk=nblk,
                n_valid=wl.N if N_pad != wl.N else None),
        in_shardings=(
            NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp)),
            None, None,
        ),
    ).lower(X, y, spec_av, idx)


def lower_predict(wl, mesh, *, schedule: str = "v2"):
    idx_np = wl.cfg.indices(wl.p)
    M = idx_np.shape[0]
    idx = jnp.asarray(idx_np)
    spec_av = _abstract_spec(wl.cfg, wl.p)
    u = jax.ShapeDtypeStruct((M,), jnp.float32)
    chol = jax.ShapeDtypeStruct((M, M), jnp.float32)
    sqrtlam = jax.ShapeDtypeStruct((M,), jnp.float32)
    if schedule == "v2":
        quantum = _n_chips(mesh)
        N_pad = (wl.N + quantum - 1) // quantum * quantum
        Xs = jax.ShapeDtypeStruct((N_pad, wl.p), jnp.float32)
        return jax.jit(
            partial(_predict_fn_v2, mesh=mesh),
        ).lower(Xs, u, chol, sqrtlam, spec_av, idx)
    dpn = _dp_size(mesh)
    N_pad = (wl.N + dpn - 1) // dpn * dpn
    Xs = jax.ShapeDtypeStruct((N_pad, wl.p), jnp.float32)
    dp = hints.dp_axes(mesh)
    return jax.jit(
        _predict_fn,
        in_shardings=(
            NamedSharding(mesh, P(dp, None)), None, None, None, None, None,
        ),
    ).lower(Xs, u, chol, sqrtlam, spec_av, idx)
