"""Approximation registry — the pluggable family layer behind ``GP``.

The paper's technique (FAGP: a decomposed kernel + Woodbury, Eqs. 8-12) is
ONE way to approximate the exact GP posterior.  This module is the seam
that makes it one of several: an :class:`Approximation` names a family
(``"fagp"``, ``"vecchia"``), declares which facade operations it supports
(capability flags), implements them against its own state type, and
provides the checkpoint hooks ``repro.checkpoint.gpstate`` serializes
through.  ``GPSpec`` carries the chosen family as the static
``approximation`` field (default ``"fagp"``, so every pre-existing spec,
checkpoint and call site is untouched) and ``core.gp.GP`` dispatches every
method through :func:`get_approximation` — the facade is the contract, the
families are plugins.

Layering: this module imports NOTHING from the rest of ``repro.core`` (it
is below ``fagp``/``vecchia``, which both import it).  Families register at
import time exactly like kernel expansions (``core.expansions``) and
execution backends (``fagp.register_backend``) do.

Refusals are STRUCTURED: an operation a family (or an execution backend)
cannot run raises :class:`UnsupportedError` carrying ``(layer, capability,
spec)`` — one error vocabulary shared by the approximation capability
flags and the backend registry's ``FitBackend.supports`` refusals (e.g.
the pallas n>64 Hermite recurrence limit).  ``UnsupportedError`` subclasses
``ValueError`` and its message always contains the phrase "does not
support", so pre-existing ``except ValueError`` / message-matching callers
keep working.
"""
from __future__ import annotations

from typing import Any, Optional

__all__ = [
    "Approximation",
    "UnsupportedError",
    "available_approximations",
    "get_approximation",
    "register_approximation",
    "require_capability",
]


def _describe(spec: Any) -> str:
    describe = getattr(spec, "describe", None)
    return describe() if callable(describe) else repr(spec)


class UnsupportedError(ValueError):
    """A layer refused an operation it does not implement for this spec.

    One structured vocabulary for every capability refusal in the stack:

    layer:      which registry refused — ``"approximation"`` (a family's
                capability flags) or ``"backend"`` (``FitBackend.supports``).
    capability: what was asked of it — a facade operation name
                (``"predict"``, ``"optimize"``, ...) for approximations,
                the backend name for backend refusals.
    spec:       the offending ``GPSpec``.

    Subclasses ``ValueError`` (the pre-protocol refusal type) and the
    message always contains "does not support".
    """

    def __init__(self, message: str, *, layer: str, capability: str,
                 spec: Any = None):
        super().__init__(message)
        self.layer = layer
        self.capability = capability
        self.spec = spec


class Approximation:
    """One registered approximation family behind the ``GP`` facade.

    Subclasses set ``name`` and ``capabilities`` and implement the
    operations they declare; anything not declared is refused with a
    structured :class:`UnsupportedError` (``GP`` checks the flags *before*
    calling, so refusal happens at the facade boundary, not deep inside a
    kernel launch).  The recognized capability flags are

        fit / predict / mean_var / update / nlml / optimize / bank

    (``bank`` marks the family as admissible to ``repro.bank.GPBank``'s
    stacked-tenant machinery).

    Checkpoint hooks (``repro.checkpoint.gpstate`` serializes any family
    through these; the manifest records ``spec.approximation`` so a restore
    resolves the right family — and manifests written before the protocol
    existed load as ``"fagp"``):

    ckpt_leaf_names: the ordered array-leaf names of the state.
    ckpt_leaves:     state -> {name: array} for exactly those names.
    ckpt_meta:       state -> extra manifest metadata (informational).
    ckpt_rebuild:    (spec, leaves, train) -> state; ``train`` is the
                     optional stored-training-data dict (FAGP's
                     ``store_train`` path; None for families that keep
                     training data among their leaves).
    """

    name: str = "abstract"
    capabilities: frozenset = frozenset()

    # -- spec validation (runs at GPSpec construction) ----------------------

    def validate(self, spec: Any) -> None:
        raise NotImplementedError

    # -- facade operations --------------------------------------------------

    def fit(self, X, y, spec):
        self.refuse("fit", spec)

    def predict(self, state, Xs, *, mode: str = "fused"):
        self.refuse("predict", getattr(state, "spec", None))

    def mean_var(self, state, Xs):
        self.refuse("mean_var", getattr(state, "spec", None))

    def update(self, state, X_new, y_new):
        self.refuse("update", getattr(state, "spec", None))

    def nlml(self, X, y, spec, *, mask=None):
        self.refuse("nlml", spec)

    def optimize(self, X, y, spec, **kwargs):
        self.refuse("optimize", spec)

    # -- checkpoint hooks ---------------------------------------------------

    def ckpt_leaf_names(self) -> tuple:
        raise NotImplementedError

    def ckpt_leaves(self, state) -> dict:
        raise NotImplementedError

    def ckpt_meta(self, state) -> dict:
        return {}

    def ckpt_rebuild(self, spec, leaves: dict, train: Optional[dict]):
        raise NotImplementedError

    # -- refusal ------------------------------------------------------------

    def refuse(self, capability: str, spec: Any) -> None:
        """Raise the structured refusal for ``capability``."""
        raise UnsupportedError(
            f"approximation {self.name!r} does not support {capability!r} "
            f"for {_describe(spec)}; its capabilities are "
            f"{sorted(self.capabilities)}",
            layer="approximation", capability=capability, spec=spec,
        )


_APPROXIMATIONS: dict = {}


def register_approximation(approx: Approximation) -> None:
    _APPROXIMATIONS[approx.name] = approx


def get_approximation(name: str) -> Approximation:
    try:
        return _APPROXIMATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown approximation {name!r}; registered: "
            f"{available_approximations()}"
        ) from None


def available_approximations() -> list:
    return sorted(_APPROXIMATIONS)


def require_capability(approx: Approximation, capability: str,
                       spec: Any) -> None:
    """The facade-boundary capability gate: raise the family's structured
    refusal unless it declares ``capability``."""
    if capability not in approx.capabilities:
        approx.refuse(capability, spec)
