"""Vecchia nearest-neighbor conditioning — the sibling approximation.

Where FAGP (the paper's technique) replaces the N x N kernel inverse by a
GLOBAL low-rank feature system, the Vecchia approximation is LOCAL: the
joint density is factorized along the data ordering and each conditional
is truncated to the k nearest preceding points,

    p(y) ~= prod_i p(y_i | y_{c(i)}),   c(i) = k nearest rows among j < i,

and prediction conditions each query on its k nearest training points.
Every solve is a k x k Cholesky — batched over rows as B x k x k lanes
(the same small-solve batching the bank and the hyperopt lane engine
exploit) — so cost is O(N k^3) with NO N x N (or Q x N) intermediate: the
conditioning sets come from the blocked streaming top-k in
``repro.kernels.knn`` (pinned by a jaxpr sweep in tests/test_vecchia.py).
This is the regime decomposed-kernel expansions handle worst — large,
clustered, short-lengthscale spatial data — and the reason ROADMAP item 3
wants it as a sibling family behind the facade rather than a fourth
expansion: its state is the raw data, not a feature-space factorization.

The family plugs in through ``core.approximation``: ``spec =
GPSpec.create_vecchia(eps, noise, kernel="se"|"matern52", neighbors=k)``
and every ``GP`` call dispatches here by ``spec.approximation``.  The
kernel oracles are the exact reference kernels (``exact_gp.KERNELS`` — the
same table the expansion parity tests trust), so as k -> N both prediction
and the ordered-factorization NLML converge to ``exact_gp`` (exactly, at
full conditioning sets: the product of conditionals telescopes to the
joint).  Declared capabilities: fit / mean_var / update / nlml.  Refused
(structured ``UnsupportedError``): ``predict`` (full Q x Q posterior
covariance — the cross-query terms need a joint conditioning set),
``optimize`` and bank admission.

Layering note: this module must not import ``fagp`` at module scope (fagp
imports it at its bottom to register the family); the spec compatibility
helpers are pulled lazily inside ``with_spec``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .approximation import (
    Approximation,
    UnsupportedError,
    register_approximation,
)
from .exact_gp import KERNELS
from repro.kernels import knn

__all__ = ["VecchiaApproximation", "VecchiaState"]

_BLOCK_Q = 128  # query rows per batched-Cholesky lane block


def _block_q(k: int) -> int:
    """Query-block size: bounded lane memory (block_q * k^2 floats)."""
    return int(max(1, min(_BLOCK_Q, (1 << 21) // max(1, k * k))))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VecchiaState:
    """A fitted Vecchia session.  The "factorization" IS the training data:
    conditioning sets and k x k solves are rebuilt per query batch, so
    ``update`` is an exact concatenation (no approximation drift) and the
    checkpoint leaves are simply (X, y)."""

    X: jax.Array                     # (N, p) training inputs
    y: jax.Array                     # (N,) or (N, T) training targets
    spec: Optional[Any] = None       # baked GPSpec (approximation="vecchia")

    @property
    def n_train(self) -> int:
        return self.X.shape[0]

    @property
    def n_tasks(self) -> int:
        return 1 if self.y.ndim == 1 else self.y.shape[1]

    @property
    def n_features(self) -> int:
        raise UnsupportedError(
            "approximation 'vecchia' does not support 'n_features': the "
            "state is the raw data, not a feature-space factorization",
            layer="approximation", capability="n_features", spec=self.spec,
        )

    def with_spec(self, spec=None, **overrides) -> "VecchiaState":
        """Same contract as :meth:`FAGPState.with_spec`: execution knobs
        (block_rows, backend) may change at serve time; structure
        (approximation, kernel, neighbors) and hyperparameters are frozen
        — refit instead (for Vecchia a refit is O(1) anyway)."""
        from . import fagp  # lazy: no module-scope fagp import here

        if spec is None:
            if self.spec is None:
                raise ValueError(
                    "state has no baked spec to override; pass a full "
                    "GPSpec: state.with_spec(spec)"
                )
            spec = dataclasses.replace(self.spec, **overrides)
        elif overrides:
            raise TypeError(
                "pass either a full spec or keyword overrides, not both"
            )
        if self.spec is not None:
            for f in fagp._STRUCTURAL_FIELDS:
                if getattr(spec, f) != getattr(self.spec, f):
                    raise ValueError(
                        f"spec/state mismatch: state was fitted with "
                        f"{self.spec.describe()} but the new spec has "
                        f"{f}={getattr(spec, f)!r}; structural choices are "
                        f"frozen into the session — refit instead"
                    )
            for f in fagp._HYPER_FIELDS:
                if not fagp._leaf_equal(
                    getattr(spec, f), getattr(self.spec, f)
                ):
                    raise ValueError(
                        f"with_spec: spec/state mismatch: {f} differs from "
                        f"the value this state was fitted with; refit "
                        f"instead"
                    )
        VECCHIA.validate(spec)
        return dataclasses.replace(self, spec=spec)


# ---------------------------------------------------------------------------
# Batched conditioning math.  Every helper below takes gathered neighbor
# blocks and runs B x k x k Cholesky lanes (one jnp.linalg.cholesky over a
# leading batch axis — the lane idiom of bank/gp_hyperopt).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("kernel", "k", "block_q", "block_t"))
def _mean_var(X, y2, Xs, eps, noise, *, kernel, k, block_q, block_t):
    """Posterior mean (Q, T) and latent marginal variance (Q,): each query
    conditions on its k nearest training rows.  Both reference kernels are
    unit-variance, so k(x, x) = 1."""
    kf = KERNELS[kernel]
    sig2 = noise**2
    Q = Xs.shape[0]
    _, idx = knn.knn_search(Xs, X, k, block_q=block_q, block_t=block_t)

    nblk = max(1, -(-Q // block_q))
    pad = nblk * block_q - Q
    Xsb = jnp.pad(Xs, ((0, pad), (0, 0))).reshape(nblk, block_q, -1)
    idxb = jnp.pad(idx, ((0, pad), (0, 0))).reshape(nblk, block_q, k)
    eye = jnp.eye(k, dtype=X.dtype)[None]

    def blk(args):
        Xq, nb = args
        Xn = X[nb]                                             # (B, k, p)
        yn = y2[nb]                                            # (B, k, T)
        Knn = jax.vmap(lambda Z: kf(Z, Z, eps))(Xn)
        ks = jax.vmap(lambda xq, Z: kf(xq[None, :], Z, eps)[0])(Xq, Xn)
        L = jnp.linalg.cholesky(Knn + sig2 * eye)
        alpha = jax.vmap(
            lambda Lc, bc: jax.scipy.linalg.cho_solve((Lc, True), bc)
        )(L, yn)
        mu = jnp.einsum("bk,bkt->bt", ks, alpha)
        w = jax.vmap(
            lambda Lc, c: jax.scipy.linalg.solve_triangular(
                Lc, c, lower=True
            )
        )(L, ks)
        var = jnp.maximum(1.0 - jnp.sum(w * w, axis=1), 0.0)
        return mu, var

    mu, var = jax.lax.map(blk, (Xsb, idxb))
    return (
        mu.reshape(-1, y2.shape[1])[:Q],
        var.reshape(-1)[:Q],
    )


@partial(jax.jit, static_argnames=("kernel", "k", "block_q", "block_t"))
def _nlml(X, y2, eps, noise, *, kernel, k, block_q, block_t):
    """Ordered-factorization NLML: sum_i -log N(y_i; mu_i, var_i) with
    (mu_i, var_i) the conditional of y_i given its (up to) k nearest
    PRECEDING rows.  At k >= N-1 the conditionals telescope to the exact
    joint, so this equals ``exact_gp.nlml`` (tests pin it).  Rows with
    fewer than k admissible neighbors (i < k) get identity-filled masked
    slots — mathematically absent, numerically inert."""
    kf = KERNELS[kernel]
    sig2 = noise**2
    N = X.shape[0]
    T = y2.shape[1]
    nbr, m = knn.ordered_topk(X, k, block_q=block_q, block_t=block_t)

    nblk = max(1, -(-N // block_q))
    pad = nblk * block_q - N
    Xb = jnp.pad(X, ((0, pad), (0, 0))).reshape(nblk, block_q, -1)
    yb = jnp.pad(y2, ((0, pad), (0, 0))).reshape(nblk, block_q, T)
    nb_ = jnp.pad(nbr, ((0, pad), (0, 0))).reshape(nblk, block_q, k)
    mb = jnp.pad(m, ((0, pad), (0, 0))).reshape(nblk, block_q, k)
    rv = jnp.pad(jnp.ones((N,), X.dtype), (0, pad)).reshape(nblk, block_q)
    eye = jnp.eye(k, dtype=X.dtype)[None]

    def blk(args):
        Xi, yi, nb, mi, rvi = args
        Xc = X[nb]                                             # (B, k, p)
        yc = y2[nb]                                            # (B, k, T)
        Kcc = jax.vmap(lambda Z: kf(Z, Z, eps))(Xc)
        ks = jax.vmap(lambda xq, Z: kf(xq[None, :], Z, eps)[0])(Xi, Xc)
        mm = mi[:, :, None] * mi[:, None, :]                   # (B, k, k)
        A = mm * (Kcc + sig2 * eye) + (1.0 - mm) * eye
        c = mi * ks                                            # (B, k)
        L = jnp.linalg.cholesky(A)
        alpha = jax.vmap(
            lambda Lc, bc: jax.scipy.linalg.cho_solve((Lc, True), bc)
        )(L, mi[:, :, None] * yc)
        mu = jnp.einsum("bk,bkt->bt", c, alpha)                # (B, T)
        w = jax.vmap(
            lambda Lc, cc: jax.scipy.linalg.solve_triangular(
                Lc, cc, lower=True
            )
        )(L, c)
        var = 1.0 + sig2 - jnp.sum(w * w, axis=1)              # (B,)
        resid = yi - mu
        nll = 0.5 * (
            T * jnp.log(2.0 * jnp.pi * var)
            + jnp.sum(resid * resid, axis=1) / var
        )
        return jnp.sum(nll * rvi)

    return jnp.sum(jax.lax.map(blk, (Xb, yb, nb_, mb, rv)))


# ---------------------------------------------------------------------------
# The registered family
# ---------------------------------------------------------------------------


def _as_2d(y: jax.Array) -> jax.Array:
    return y if y.ndim == 2 else y[:, None]


class VecchiaApproximation(Approximation):
    """``spec.approximation == "vecchia"``: nearest-neighbor conditioning
    with ``spec.kernel`` in {'se', 'matern52'} (the exact reference
    oracles) and ``spec.neighbors`` = k."""

    name = "vecchia"
    capabilities = frozenset({"fit", "mean_var", "update", "nlml"})
    state_type = VecchiaState

    # -- spec validation ----------------------------------------------------

    def validate(self, spec) -> None:
        if spec.kernel not in KERNELS:
            raise ValueError(
                f"vecchia kernel must be one of {sorted(KERNELS)}, got "
                f"{spec.kernel!r}"
            )
        if spec.neighbors is None or int(spec.neighbors) < 1:
            raise ValueError(
                f"vecchia needs neighbors >= 1 (the conditioning-set size "
                f"k), got {spec.neighbors!r}"
            )
        if spec.omega is not None:
            raise ValueError(
                "vecchia takes no spectral draws (omega); it evaluates the "
                "exact kernel on k-neighbor sets"
            )

    # -- blocking knobs -----------------------------------------------------

    @staticmethod
    def _blocks(spec, n_train: int) -> tuple:
        k = int(spec.neighbors)
        return _block_q(k), max(1, min(int(spec.block_rows), n_train))

    # -- facade operations --------------------------------------------------

    def fit(self, X, y, spec) -> VecchiaState:
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        if X.ndim != 2:
            raise ValueError(f"X must be (N, p), got shape {X.shape}")
        if spec.p != X.shape[1]:
            raise ValueError(
                f"spec/input mismatch: {spec.describe()} was built for "
                f"p={spec.p} input dimensions but the data has "
                f"p={X.shape[1]}"
            )
        if y.shape[0] != X.shape[0]:
            raise ValueError(
                f"X has {X.shape[0]} rows but y has {y.shape[0]}"
            )
        if int(spec.neighbors) > X.shape[0]:
            raise ValueError(
                f"vecchia neighbors={int(spec.neighbors)} exceeds the "
                f"training-set size N={X.shape[0]}; choose k <= N"
            )
        return VecchiaState(X=X, y=y, spec=spec)

    def mean_var(self, state: VecchiaState, Xs):
        spec = state.spec
        k = int(spec.neighbors)
        bq, bt = self._blocks(spec, state.n_train)
        mu, var = _mean_var(
            state.X, _as_2d(state.y), jnp.asarray(Xs), spec.eps, spec.noise,
            kernel=spec.kernel, k=k, block_q=bq, block_t=bt,
        )
        return (mu[:, 0] if state.y.ndim == 1 else mu), var

    def update(self, state: VecchiaState, X_new, y_new) -> VecchiaState:
        X_new = jnp.asarray(X_new)
        y_new = jnp.asarray(y_new)
        if y_new.ndim != state.y.ndim or (
            y_new.ndim == 2 and y_new.shape[1] != state.y.shape[1]
        ):
            raise ValueError(
                f"update task mismatch: state holds {state.n_tasks} "
                f"task(s) but y_new has shape {y_new.shape}"
            )
        return dataclasses.replace(
            state,
            X=jnp.concatenate([state.X, X_new], axis=0),
            y=jnp.concatenate([state.y, y_new], axis=0),
        )

    def nlml(self, X, y, spec, *, mask=None):
        if mask is not None:
            raise UnsupportedError(
                f"approximation 'vecchia' does not support 'nlml_mask' for "
                f"{spec.describe()}: the ordered factorization has no "
                f"masked-row form yet",
                layer="approximation", capability="nlml_mask", spec=spec,
            )
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        k = min(int(spec.neighbors), X.shape[0])
        bq, bt = self._blocks(spec, X.shape[0])
        return _nlml(
            X, _as_2d(y), spec.eps, spec.noise,
            kernel=spec.kernel, k=k, block_q=bq, block_t=bt,
        )

    # -- checkpoint hooks ---------------------------------------------------

    def ckpt_leaf_names(self) -> tuple:
        return ("X", "y")

    def ckpt_leaves(self, state: VecchiaState) -> dict:
        return {"X": state.X, "y": state.y}

    def ckpt_meta(self, state: VecchiaState) -> dict:
        return {"N": int(state.n_train), "n_tasks": int(state.n_tasks)}

    def ckpt_rebuild(self, spec, leaves: dict, train) -> VecchiaState:
        return VecchiaState(X=leaves["X"], y=leaves["y"], spec=spec)


VECCHIA = VecchiaApproximation()
register_approximation(VECCHIA)
