"""Mercer eigen-decomposition of the squared-exponential (SE) kernel.

Implements the analytical eigensystem of the SE kernel w.r.t. a Gaussian
measure, following Fasshauer & McCourt (2012) as used by the paper
(Carminati 2024, Eqs. 13-20):

    k_SE(x, x') = exp(-eps^2 (x - x')^2)                       (1-D, Eq. 13)

    beta    = (1 + (2 eps / rho)^2)^(1/4)                      (Eq. 14)
    gamma_i = sqrt(beta / (2^(i-1) Gamma(i)))
    delta^2 = rho^2 / 2 * (beta^2 - 1)

    phi_i(x)  = gamma_i exp(-delta^2 x^2) H_{i-1}(rho beta x)  (Eq. 15)
    lambda_i  = sqrt(rho^2 / (rho^2 + delta^2 + eps^2))
                * (eps^2 / (rho^2 + delta^2 + eps^2))^(i-1)    (Eq. 16)

NOTE (paper typo, recorded in DESIGN.md): the paper prints
``delta^2 = rho/2 (beta^2 - 1)``; its cited source (Fasshauer & McCourt 2012,
Eq. 3.9 with alpha = rho) has ``rho^2 / 2``.  Only the latter reproduces
``sum_i lambda_i phi_i(x) phi_i(x') -> k_SE(x, x')``; the property test
``test_mercer_reconstruction`` pins this down numerically.

Multidimensional (ARD) generalization, paper Eqs. 17-20: tensor products of
the 1-D eigensystem over multi-indices ``n in N^p``.  The paper uses the full
grid ``{1..n}^p`` (size n^p, its stated limitation).  Beyond the paper, this
module also provides *total-degree* and *hyperbolic-cross* index sets that
exploit the product structure of ``lambda_n`` to reach the same accuracy with
polynomially many columns.

All feature evaluation uses a scaled Hermite recurrence that folds gamma_i
into the iteration (Hermite-function style), so magnitudes stay f32-safe far
beyond the degree ~30 where classical H_i overflow.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SEKernelParams",
    "mercer_constants",
    "eigenvalues_1d",
    "log_eigenvalues_1d",
    "log_eigenvalues_nd",
    "eigenfunctions_1d",
    "hermite_psi_rows",
    "full_grid",
    "total_degree",
    "hyperbolic_cross",
    "make_index_set",
    "eigenvalues_nd",
    "phi_nd",
    "k_se_ard",
    "k_matern52_ard",
]

IndexSetKind = Literal["full", "total_degree", "hyperbolic_cross"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SEKernelParams:
    """ARD squared-exponential kernel + Mercer-expansion hyperparameters.

    eps:   per-dimension inverse length scales, shape (p,). Paper's eps_j.
    rho:   per-dimension global scale factors,  shape (p,). Paper's rho_j;
           controls eigenvalue decay speed.
    noise: observation noise std sigma_n (scalar).
    """

    eps: jax.Array
    rho: jax.Array
    noise: jax.Array

    @property
    def p(self) -> int:
        return self.eps.shape[0]

    @staticmethod
    def create(eps, rho, noise=1e-2) -> "SEKernelParams":
        eps = jnp.atleast_1d(jnp.asarray(eps, jnp.float32))
        rho = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), eps.shape)
        return SEKernelParams(eps=eps, rho=rho, noise=jnp.asarray(noise, jnp.float32))


def mercer_constants(eps: jax.Array, rho: jax.Array):
    """Paper Eq. 14 constants (with the delta^2 = rho^2/2 (beta^2-1) fix).

    Returns (beta, delta2) broadcast over the shapes of eps/rho.
    """
    beta = (1.0 + (2.0 * eps / rho) ** 2) ** 0.25
    delta2 = 0.5 * rho**2 * (beta**2 - 1.0)
    return beta, delta2


def log_eigenvalues_1d(n: int, eps: jax.Array, rho: jax.Array) -> jax.Array:
    """log of paper Eq. 16 eigenvalues.  lambda_i decays geometrically and
    underflows f32 near i ~ 40, so all downstream consumers work in log space
    (see fagp.py's symmetrically-scaled solve).  Returns (n,)."""
    _, delta2 = mercer_constants(eps, rho)
    denom = rho**2 + delta2 + eps**2
    i = jnp.arange(n, dtype=jnp.float32)  # i-1 in paper indexing
    return 0.5 * (jnp.log(rho**2) - jnp.log(denom)) + i * (
        jnp.log(eps**2) - jnp.log(denom)
    )


def eigenvalues_1d(n: int, eps: jax.Array, rho: jax.Array) -> jax.Array:
    """Paper Eq. 16: the first ``n`` SE-kernel eigenvalues for one dimension."""
    return jnp.exp(log_eigenvalues_1d(n, eps, rho))


def hermite_psi_rows(z: jax.Array, beta: jax.Array, n: int) -> list:
    """THE single home of the gamma-scaled Hermite recurrence.

    With z = rho*beta*x and psi_i = gamma_i H_{i-1}(z):

        psi_1     = sqrt(beta)
        psi_2     = sqrt(2) z psi_1
        psi_{i+1} = z sqrt(2/i) psi_i - sqrt((i-1)/i) psi_{i-1}

    following from H_i = 2 z H_{i-1} - 2(i-1) H_{i-2} and
    gamma_{i+1}/gamma_i = 1/sqrt(2i).  Unrolled at trace time (n is static)
    so the same code runs in plain jnp (``eigenfunctions_1d``) and inside a
    Pallas kernel body (``kernels.hermite_phi.phi_tile``), where a
    ``lax.scan`` is not available; returns the list [psi_1 .. psi_n] of
    arrays shaped like ``z``, *without* the Gaussian envelope.
    """
    psi_prev = jnp.sqrt(beta) * jnp.ones_like(z)
    rows = [psi_prev]
    if n > 1:
        psi_cur = z * np.float32(np.sqrt(2.0)) * psi_prev
        rows.append(psi_cur)
        for i in range(2, n):
            nxt = z * np.float32(np.sqrt(2.0 / i)) * psi_cur \
                - np.float32(np.sqrt((i - 1.0) / i)) * psi_prev
            psi_prev, psi_cur = psi_cur, nxt
            rows.append(nxt)
    return rows


def eigenfunctions_1d(x: jax.Array, n: int, eps: jax.Array, rho: jax.Array) -> jax.Array:
    """Paper Eq. 15: phi_i(x) = gamma_i exp(-delta^2 x^2) H_{i-1}(rho beta x).

    x: (...,) scalars for one input dimension. Returns (..., n).

    Stable scaled recurrence via :func:`hermite_psi_rows` (shared with the
    Pallas tile builder — one implementation, two execution contexts).
    """
    beta, delta2 = mercer_constants(eps, rho)
    z = rho * beta * x
    envelope = jnp.exp(-delta2 * x * x)
    psis = jnp.stack(hermite_psi_rows(z, beta, n), axis=0)
    return jnp.moveaxis(psis, 0, -1) * envelope[..., None]


# ---------------------------------------------------------------------------
# Multi-index sets (static / numpy: shapes must be known at trace time)
# ---------------------------------------------------------------------------


def full_grid(n: int, p: int) -> np.ndarray:
    """Paper Eq. 18: all n^p combinations. (M, p) int32, degrees 0-based."""
    grids = np.meshgrid(*[np.arange(n)] * p, indexing="ij")
    idx = np.stack([g.reshape(-1) for g in grids], axis=-1).astype(np.int32)
    return idx


def total_degree(n: int, p: int, degree: int | None = None) -> np.ndarray:
    """Multi-indices with sum of (0-based) degrees <= degree. Polynomial size."""
    if degree is None:
        degree = n - 1
    idx = full_grid(min(n, degree + 1), p)
    keep = idx.sum(axis=1) <= degree
    return np.ascontiguousarray(idx[keep])


def hyperbolic_cross(n: int, p: int, degree: int | None = None) -> np.ndarray:
    """Multi-indices with prod of (1-based) degrees <= degree.

    Matched to the product structure lambda_n = prod_j lambda_{n_j}: keeps
    exactly the indices whose product eigenvalue is large. Near-linear size.
    """
    if degree is None:
        degree = n
    idx = full_grid(min(n, degree), p)
    keep = np.prod(idx + 1, axis=1) <= degree
    return np.ascontiguousarray(idx[keep])


def make_index_set(kind: IndexSetKind, n: int, p: int, degree: int | None = None) -> np.ndarray:
    if kind == "full":
        return full_grid(n, p)
    if kind == "total_degree":
        return total_degree(n, p, degree)
    if kind == "hyperbolic_cross":
        return hyperbolic_cross(n, p, degree)
    raise ValueError(f"unknown index set kind: {kind!r}")


# ---------------------------------------------------------------------------
# N-dimensional eigensystem (paper Eqs. 19-20)
# ---------------------------------------------------------------------------


def log_eigenvalues_nd(idx: jax.Array, params: SEKernelParams) -> jax.Array:
    """log lambda_n = sum_j log lambda_{n_j}  (Eq. 20). idx: (M, p) -> (M,)."""
    p = params.p

    def per_dim(j):
        _, delta2 = mercer_constants(params.eps[j], params.rho[j])
        denom = params.rho[j] ** 2 + delta2 + params.eps[j] ** 2
        i = idx[:, j].astype(jnp.float32)
        return 0.5 * (jnp.log(params.rho[j] ** 2) - jnp.log(denom)) + i * (
            jnp.log(params.eps[j] ** 2) - jnp.log(denom)
        )

    return sum(per_dim(j) for j in range(p))


def eigenvalues_nd(idx: jax.Array, params: SEKernelParams) -> jax.Array:
    """lambda_n = prod_j lambda_{n_j}  (Eq. 20). idx: (M, p) -> (M,)."""
    return jnp.exp(log_eigenvalues_nd(idx, params))


def phi_nd(X: jax.Array, idx: jax.Array, params: SEKernelParams, n_max: int) -> jax.Array:
    """Phi_(X): tensor-product eigenfunctions (Eq. 19).

    X: (N, p) samples; idx: (M, p) multi-indices (0-based); n_max: 1 + max
    degree (static). Returns (N, M) with Phi[a, m] = prod_j phi_{idx[m,j]}(X[a,j]).

    This is the pure-jnp reference path; the Pallas kernel
    ``repro.kernels.hermite_phi`` fuses the same computation for TPU.
    """
    N, p = X.shape
    feats = []
    for j in range(p):
        f_j = eigenfunctions_1d(X[:, j], n_max, params.eps[j], params.rho[j])  # (N, n_max)
        feats.append(f_j)
    out = jnp.ones((N, idx.shape[0]), X.dtype)
    for j in range(p):
        out = out * jnp.take(feats[j], idx[:, j], axis=1)  # (N, M)
    return out


def k_se_ard(X: jax.Array, X2: jax.Array, eps: jax.Array) -> jax.Array:
    """Exact ARD SE kernel (paper Eq. 17): exp(-sum_j eps_j^2 (x_j-x'_j)^2)."""
    d = X[:, None, :] - X2[None, :, :]  # (N, N2, p)
    return jnp.exp(-jnp.sum((eps**2) * d * d, axis=-1))


def k_matern52_ard(X: jax.Array, X2: jax.Array, eps: jax.Array) -> jax.Array:
    """Exact ARD Matern-5/2 kernel, parametrized to match the SE convention:
    the SE kernel exp(-eps^2 d^2) has lengthscale l = 1/(sqrt(2) eps), so the
    Matern scaled distance is r^2 = sum_j (x_j - x'_j)^2 / l_j^2
    = 2 sum_j eps_j^2 (x_j - x'_j)^2 and

        k(r) = (1 + sqrt(5) r + 5 r^2 / 3) exp(-sqrt(5) r).

    This is the parity oracle for the RFF-Matern expansion (whose spectral
    frequencies are multivariate-t with 2*nu = 5 degrees of freedom)."""
    d = X[:, None, :] - X2[None, :, :]  # (N, N2, p)
    r2 = 2.0 * jnp.sum((eps**2) * d * d, axis=-1)
    r = jnp.sqrt(jnp.maximum(r2, 1e-30))
    s5r = jnp.sqrt(5.0) * r
    return (1.0 + s5r + (5.0 / 3.0) * r2) * jnp.exp(-s5r)
