"""`GP` — one facade over the whole predictive-posterior pipeline.

The paper's contribution is a single pipeline (Eqs. 8-12) run end-to-end on
an accelerator; this module exposes it as a single self-describing session
object instead of six free functions that each re-take configuration:

    from repro.core.gp import GP, GPSpec

    spec = GPSpec.create(n=8, eps=[0.8, 0.8], noise=0.05)
    gp = GP.fit(X, y, spec)              # spec baked into the session
    mu, cov = gp.predict(Xs)             # nothing re-passed
    mu, var = gp.mean_var(Xs)            # serving path (marginal variance)
    gp = gp.update(X_new, y_new)         # rank-k ingest, no refit
    loss = gp.nlml(X, y)                 # NLML under the session's spec
    gp = GP.optimize(X, y, spec)         # gradient NLML hyperparameter fit

    gp.with_spec(backend="pallas")       # serve-time backend swap (validated)

`GP` is an immutable pytree wrapping a fitted state; every method returns
results or a new `GP`.  Multi-output targets ``y`` of shape ``(N, T)``
share one factorization with per-task mean weights — ``predict``/
``mean_var`` then return ``(N*, T)`` means and a shared variance.
`serve_gp`, `core.distributed` and the benchmarks all speak this one
interface.

TWO things are pluggable behind the facade, at different layers:

* the APPROXIMATION FAMILY (``spec.approximation``, a registered
  :class:`~repro.core.approximation.Approximation`): every method below
  dispatches through the family's protocol adapter.  ``"fagp"`` (default)
  is the paper's decomposed-kernel technique with its expansion/backend
  machinery; ``"vecchia"`` (``core.vecchia``) is nearest-neighbor
  conditioning for the clustered-spatial regime —

      spec = GPSpec.create_vecchia([2.0, 2.0], 0.1, kernel="matern52",
                                   neighbors=32)
      gp = GP.fit(X, y, spec)          # same calls, different family
      mu, var = gp.mean_var(Xs)

  A family declares capability flags; calling a method it does not
  implement (e.g. ``predict``/``optimize`` on vecchia) raises the
  structured :class:`~repro.core.approximation.UnsupportedError` at the
  facade boundary, before any compute.

* within the FAGP family, the KERNEL EXPANSION (``spec.expansion`` names a
  registered :class:`~repro.core.expansions.KernelExpansion`): the same
  facade serves the paper's Hermite-Mercer eigen-expansion (default) and
  the random-Fourier families —

      spec = GPSpec.create_rff([0.8, 0.8], kernel="matern52",
                               num_features=256, seed=0)
      gp = GP.fit(X, y, spec)          # same calls, different kernel

``GP.optimize`` learns RFF lengthscales exactly like Mercer ones (the
spectral draws are data leaves on the spec; eps scales them inside the
feature map).  The split ``(params, cfg)`` call shapes were deprecated for
two releases and now raise TypeError (README §Migration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from . import fagp  # noqa: F401  (fagp registers both families on import)
from .approximation import (
    Approximation,
    UnsupportedError,
    get_approximation,
    require_capability,
)
from .fagp import FAGPState, GPSpec

__all__ = ["GP", "GPSpec", "Approximation", "UnsupportedError"]


def _approx_for(spec: Optional[GPSpec]) -> Approximation:
    if spec is None:
        raise ValueError(
            "state has no baked GPSpec; attach one with "
            "state.with_spec(spec) first"
        )
    return get_approximation(spec.approximation)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GP:
    """A fitted GP session: the state (with its spec baked in) plus methods.

    ``state`` is whatever the spec's approximation family fits —
    :class:`~repro.core.fagp.FAGPState` for ``"fagp"``,
    :class:`~repro.core.vecchia.VecchiaState` for ``"vecchia"``.  Construct
    with :meth:`fit`, :meth:`optimize`, or :meth:`from_state`; the default
    constructor is for internal use.
    """

    state: Any

    # -- constructors -------------------------------------------------------

    @classmethod
    def fit(cls, X: jax.Array, y: jax.Array, spec: GPSpec) -> "GP":
        """Fit the posterior; y is (N,) or (N, T) for T tasks sharing one
        factorization.  The spec is baked into the session."""
        ap = _approx_for(spec)
        require_capability(ap, "fit", spec)
        return cls(state=ap.fit(X, y, spec))

    @classmethod
    def from_state(cls, state) -> "GP":
        """Wrap an existing fitted state (e.g. from ``fit_distributed``)."""
        if state.spec is None:
            raise ValueError(
                "state has no baked GPSpec; attach one with "
                "state.with_spec(spec) before wrapping it in a GP"
            )
        return cls(state=state)

    @classmethod
    def optimize(
        cls,
        X: jax.Array,
        y: jax.Array,
        spec: GPSpec,
        *,
        steps: int = 100,
        lr: float = 5e-2,
        restarts: int = 1,
        tol: Optional[float] = None,
        jitter: float = 0.3,
        seed: int = 0,
        callback: Optional[Callable[[int, float, GPSpec], None]] = None,
    ) -> "GP":
        """Gradient-based NLML hyperparameter learning (the paper's declared
        future work), then fit at the learned hyperparameters.

        Minimizes ``nlml(X, y, spec)/N`` over (eps, rho, noise) in log space
        with AdamW on the fleet lane engine (``repro.optim.gp_hyperopt`` —
        the same engine ``GPBank.optimize`` runs for whole tenant fleets):
        ``restarts`` lanes start from log-space jittered inits (restart 0
        is always the unperturbed spec) and are stepped together in ONE
        compiled executable, the best lane by final NLML wins, and ``tol``
        freezes converged lanes early.  The moment accumulation inside the
        objective streams through the backend registry, so optimization
        never materializes the N x M feature matrix on either backend.

        ``callback(step, nlml_per_row, current_spec)`` is invoked every 10%
        of the run with the currently-best lane's loss and hyperparameters.

        Families that do not declare the ``optimize`` capability (vecchia,
        for now) refuse here with a structured ``UnsupportedError``.
        """
        ap = _approx_for(spec)
        require_capability(ap, "optimize", spec)
        return cls(state=ap.optimize(
            X, y, spec, steps=steps, lr=lr, restarts=restarts, tol=tol,
            jitter=jitter, seed=seed, callback=callback,
        ))

    # -- introspection ------------------------------------------------------

    @property
    def spec(self) -> GPSpec:
        return self.state.spec

    @property
    def approximation(self) -> Approximation:
        """The session's registered approximation family."""
        return _approx_for(self.spec)

    @property
    def n_features(self) -> int:
        """M, the number of Mercer features (size of the fitted system);
        FAGP-family sessions only."""
        return self.state.n_features

    @property
    def n_tasks(self) -> int:
        return self.state.n_tasks

    # -- the pipeline -------------------------------------------------------

    def predict(self, Xs: jax.Array, *, mode: str = "fused"):
        """Posterior mean and full covariance at Xs (paper Eqs. 11-12)."""
        ap = self.approximation
        require_capability(ap, "predict", self.spec)
        return ap.predict(self.state, Xs, mode=mode)

    def mean_var(self, Xs: jax.Array):
        """Posterior mean and marginal variance — the serving path."""
        ap = self.approximation
        require_capability(ap, "mean_var", self.spec)
        return ap.mean_var(self.state, Xs)

    def update(self, X_new: jax.Array, y_new: jax.Array) -> "GP":
        """Absorb new observations (FAGP: rank-k Cholesky update; vecchia:
        exact concatenation into the conditioning pool)."""
        ap = self.approximation
        require_capability(ap, "update", self.spec)
        return GP(state=ap.update(self.state, X_new, y_new))

    def nlml(self, X: jax.Array, y: jax.Array):
        """NLML of (X, y) under this session's spec."""
        ap = self.approximation
        require_capability(ap, "nlml", self.spec)
        return ap.nlml(X, y, self.spec)

    def with_spec(self, spec: Optional[GPSpec] = None, **overrides) -> "GP":
        """Serve-time escape hatch: swap execution knobs (backend,
        block_rows); structural changes are rejected (see
        :meth:`FAGPState.with_spec`)."""
        return GP(state=self.state.with_spec(spec, **overrides))

    # -- durability ----------------------------------------------------------

    def save(self, ckpt_dir, *, step: Optional[int] = None) -> int:
        """Serialize this session under ``ckpt_dir`` (versioned: each save
        lands as ``step_<version>``; ``step=None`` auto-increments).  The
        manifest records the spec's structure — approximation family,
        expansion, truncation, an omega hash — so :meth:`load` round-trips
        bit-exactly and a restore into an incompatible spec raises.
        Returns the version written."""
        from repro.checkpoint import gpstate

        return gpstate.save_state(ckpt_dir, self.state, step=step)

    @classmethod
    def load(cls, ckpt_dir, *, step: Optional[int] = None,
             spec: Optional[GPSpec] = None) -> "GP":
        """Restore a session saved by :meth:`save` (``step=None`` loads the
        newest version).  The spec is rebuilt from the checkpoint itself —
        hyperparameter leaves, omega draws, approximation tag and all
        (manifests from before the approximation protocol load as
        ``"fagp"``).  Passing ``spec`` validates the checkpoint against it
        (structure AND hyperparameters, like ``with_spec``) and raises on
        mismatch."""
        from repro.checkpoint import gpstate

        _, state, _ = gpstate.load_state(
            ckpt_dir, step=step, like_spec=spec, require_hypers_match=True,
        )
        return cls(state=state)
