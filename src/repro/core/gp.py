"""`GP` — one facade over the whole predictive-posterior pipeline.

The paper's contribution is a single pipeline (Eqs. 8-12) run end-to-end on
an accelerator; this module exposes it as a single self-describing session
object instead of six free functions that each re-take configuration:

    from repro.core.gp import GP, GPSpec

    spec = GPSpec.create(n=8, eps=[0.8, 0.8], noise=0.05)
    gp = GP.fit(X, y, spec)              # spec baked into the session
    mu, cov = gp.predict(Xs)             # nothing re-passed
    mu, var = gp.mean_var(Xs)            # serving path (marginal variance)
    gp = gp.update(X_new, y_new)         # rank-k ingest, no refit
    loss = gp.nlml(X, y)                 # NLML under the session's spec
    gp = GP.optimize(X, y, spec)         # gradient NLML hyperparameter fit

    gp.with_spec(backend="pallas")       # serve-time backend swap (validated)

`GP` is an immutable pytree wrapping the fitted :class:`FAGPState`; every
method returns results or a new `GP`.  Multi-output targets ``y`` of shape
``(N, T)`` share one M x M Cholesky factorization with per-task mean
weights — ``predict``/``mean_var`` then return ``(N*, T)`` means and a
shared variance.  `serve_gp`, `core.distributed` and the benchmarks all
speak this one interface.

The kernel decomposition is pluggable (``spec.expansion`` names a
registered :class:`~repro.core.expansions.KernelExpansion`): the same
facade serves the paper's Hermite-Mercer eigen-expansion (default) and the
random-Fourier families —

    spec = GPSpec.create_rff([0.8, 0.8], kernel="matern52",
                             num_features=256, seed=0)
    gp = GP.fit(X, y, spec)              # same calls, different kernel

``GP.optimize`` learns RFF lengthscales exactly like Mercer ones (the
spectral draws are data leaves on the spec; eps scales them inside the
feature map).  The split ``(params, cfg)`` call shapes were deprecated for
two releases and now raise TypeError (README §Migration).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fagp
from .fagp import FAGPState, GPSpec

__all__ = ["GP", "GPSpec"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GP:
    """A fitted GP session: the state (with its spec baked in) plus methods.

    Construct with :meth:`fit`, :meth:`optimize`, or :meth:`from_state`; the
    default constructor is for internal use.
    """

    state: FAGPState

    # -- constructors -------------------------------------------------------

    @classmethod
    def fit(cls, X: jax.Array, y: jax.Array, spec: GPSpec) -> "GP":
        """Fit the posterior; y is (N,) or (N, T) for T tasks sharing one
        factorization.  The spec is baked into the session."""
        return cls(state=fagp.fit(X, y, spec))

    @classmethod
    def from_state(cls, state: FAGPState) -> "GP":
        """Wrap an existing fitted state (e.g. from ``fit_distributed``)."""
        if state.spec is None:
            raise ValueError(
                "state has no baked GPSpec; attach one with "
                "state.with_spec(spec) before wrapping it in a GP"
            )
        return cls(state=state)

    @classmethod
    def optimize(
        cls,
        X: jax.Array,
        y: jax.Array,
        spec: GPSpec,
        *,
        steps: int = 100,
        lr: float = 5e-2,
        restarts: int = 1,
        tol: Optional[float] = None,
        jitter: float = 0.3,
        seed: int = 0,
        callback: Optional[Callable[[int, float, GPSpec], None]] = None,
    ) -> "GP":
        """Gradient-based NLML hyperparameter learning (the paper's declared
        future work), then fit at the learned hyperparameters.

        Minimizes ``nlml(X, y, spec)/N`` over (eps, rho, noise) in log space
        with AdamW on the fleet lane engine (``repro.optim.gp_hyperopt`` —
        the same engine ``GPBank.optimize`` runs for whole tenant fleets):
        ``restarts`` lanes start from log-space jittered inits (restart 0
        is always the unperturbed spec) and are stepped together in ONE
        compiled executable, the best lane by final NLML wins, and ``tol``
        freezes converged lanes early.  The moment accumulation inside the
        objective streams through the backend registry, so optimization
        never materializes the N x M feature matrix on either backend.

        ``callback(step, nlml_per_row, current_spec)`` is invoked every 10%
        of the run with the currently-best lane's loss and hyperparameters.
        """
        from repro.optim import gp_hyperopt

        def cb(step, vals, hp):
            if callback is None:
                return
            r = int(np.argmin(vals[0]))
            lane = {f: leaf[0, r] for f, leaf in hp.items()}
            callback(
                step, float(vals[0, r]),
                dataclasses.replace(
                    spec,
                    eps=jnp.exp(lane["log_eps"]),
                    rho=jnp.exp(lane["log_rho"]),
                    noise=jnp.exp(lane["log_noise"]),
                ),
            )

        result = gp_hyperopt.optimize_restarts(
            X, y, spec, restarts=restarts, steps=steps, lr=lr, tol=tol,
            jitter=jitter, seed=seed, callback=cb,
        )
        return cls.fit(X, y, result.spec_for(spec, 0))

    # -- introspection ------------------------------------------------------

    @property
    def spec(self) -> GPSpec:
        return self.state.spec

    @property
    def n_features(self) -> int:
        """M, the number of Mercer features (size of the fitted system)."""
        return self.state.n_features

    @property
    def n_tasks(self) -> int:
        return self.state.n_tasks

    # -- the pipeline -------------------------------------------------------

    def predict(self, Xs: jax.Array, *, mode: str = "fused"):
        """Posterior mean and full covariance at Xs (paper Eqs. 11-12)."""
        return fagp.predict(self.state, Xs, mode=mode)

    def mean_var(self, Xs: jax.Array):
        """Posterior mean and marginal variance — the serving path."""
        return fagp.predict_mean_var(self.state, Xs)

    def update(self, X_new: jax.Array, y_new: jax.Array) -> "GP":
        """Absorb new observations via the rank-k Cholesky update."""
        return GP(state=fagp.fit_update(self.state, X_new, y_new))

    def nlml(self, X: jax.Array, y: jax.Array):
        """NLML of (X, y) under this session's spec."""
        return fagp.nlml(X, y, self.spec)

    def with_spec(self, spec: Optional[GPSpec] = None, **overrides) -> "GP":
        """Serve-time escape hatch: swap execution knobs (backend,
        block_rows); structural changes are rejected (see
        :meth:`FAGPState.with_spec`)."""
        return GP(state=self.state.with_spec(spec, **overrides))

    # -- durability ----------------------------------------------------------

    def save(self, ckpt_dir, *, step: Optional[int] = None) -> int:
        """Serialize this session under ``ckpt_dir`` (versioned: each save
        lands as ``step_<version>``; ``step=None`` auto-increments).  The
        manifest records the spec's structure — expansion, truncation, an
        omega hash — so :meth:`load` round-trips bit-exactly and a restore
        into an incompatible spec raises.  Returns the version written."""
        from repro.checkpoint import gpstate

        return gpstate.save_state(ckpt_dir, self.state, step=step)

    @classmethod
    def load(cls, ckpt_dir, *, step: Optional[int] = None,
             spec: Optional[GPSpec] = None) -> "GP":
        """Restore a session saved by :meth:`save` (``step=None`` loads the
        newest version).  The spec is rebuilt from the checkpoint itself —
        hyperparameter leaves, omega draws and all.  Passing ``spec``
        validates the checkpoint against it (structure AND
        hyperparameters, like ``with_spec``) and raises on mismatch."""
        from repro.checkpoint import gpstate

        _, state, _ = gpstate.load_state(
            ckpt_dir, step=step, like_spec=spec, require_hypers_match=True,
        )
        return cls(state=state)
