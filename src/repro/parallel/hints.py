"""Context-scoped sharding hints.

Model code stays mesh-agnostic: it calls ``constrain(x, logical_axes)`` and
``active_mesh()``; when no mesh is activated (unit tests, single-device
smoke runs) these are no-ops.  ``launch/*`` activates the production mesh
around lowering/execution.

Logical axis vocabulary: 'dp' (pod x data), 'data', 'model', None.
Constraints silently drop axes the dimension size cannot divide.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activate", "active_mesh", "constrain", "resolve", "dp_axes", "sp_scope", "sp_enabled"]

_STATE = threading.local()


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


@contextlib.contextmanager
def activate(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def sp_scope(on: bool = True):
    """Scopes the sequence-parallel residual pin to training (§Perf V1):
    the win comes from sharding saved-for-backward stacks; forward-only
    paths (prefill) only pay the gathers, so they leave it off."""
    prev = getattr(_STATE, "sp", False)
    _STATE.sp = on
    try:
        yield
    finally:
        _STATE.sp = prev


def sp_enabled() -> bool:
    return getattr(_STATE, "sp", False)


def resolve(mesh: Mesh, logical):
    if logical is None:
        return None
    if logical == "dp":
        return dp_axes(mesh)
    if logical == "dpm":  # every mesh axis: embarrassingly parallel row work
        return tuple(mesh.axis_names)
    return logical


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    names = ax if isinstance(ax, tuple) else (ax,)
    return int(np.prod([mesh.shape[a] for a in names]))


def constrain(x, logical_axes):
    """with_sharding_constraint if a mesh is active; no-op otherwise."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, logical_axes):
        r = resolve(mesh, ax)
        spec.append(r if (r is None or dim % _axis_size(mesh, r) == 0) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
