"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At multi-pod scale the gradient all-reduce over the 'pod' axis crosses the
slowest links (DCI); compressing the payload 4x (f32 -> int8 + per-block
scales) with error feedback (residual carried into the next step) trades a
bounded, self-correcting quantization error for wire time.

Usage (train step):

    comp = CompressionState.init(grads_like)
    grads, comp = compress_allreduce(grads, comp, axis="pod")

Property tests (test_compress.py): (a) decompress(compress(g)) error is
bounded by the block max / 127, (b) with error feedback the *accumulated*
bias over steps stays bounded (errors don't compound), (c) the compressed
all-reduce of identical shards equals the plain mean.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "quantize", "dequantize", "compress_allreduce"]

BLOCK = 256


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    """Per-leaf error-feedback residuals."""

    residual: Any

    @staticmethod
    def init(grads_like):
        return CompressionState(
            residual=jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
            )
        )


def _pad_flat(x):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize(x):
    """f32 -> (int8 blocks, f32 per-block scales). Blockwise symmetric."""
    flat, pad = _pad_flat(x)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_allreduce(grads, state: CompressionState, axis: str | tuple):
    """Quantize (grad + residual), psum-of-dequantized, update residuals.

    Must be called inside shard_map (needs a named axis). The reduction is
    performed on the dequantized values (bit-identical across members), so
    the result is exactly mean(dequantized shards).
    """
    n = jax.lax.psum(1, axis)

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = quantize(v)
        deq = dequantize(q, s, g.shape)
        new_r = v - deq                      # error feedback
        avg = jax.lax.psum(deq, axis) / n
        return avg.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, state.residual)
    new_grads = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, CompressionState(residual=new_res)
