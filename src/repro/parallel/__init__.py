"""Distribution layer: sharding rules, hints, pipeline, compression."""
from . import compress, hints, pipeline, sharding
