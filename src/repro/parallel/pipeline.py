"""GPipe-style pipeline parallelism over shard_map + collective_permute.

The production mesh reserves 'model' for TP/EP, but at >512-chip scale an
additional stage dimension becomes necessary (PP is the only parallelism
whose communication volume is O(activations) per stage boundary, not
O(weights)).  This module provides the schedule as a composable primitive:

  * layer stack split into S = mesh.shape[axis] stages, stage i resident on
    shard i (weights never move);
  * M microbatches streamed through; at every step each stage computes its
    current microbatch and hands the activation to the next stage with ONE
    collective_permute (ring neighbor — the cheapest possible collective);
  * fill/drain bubbles of the classic GPipe schedule: efficiency
    M / (M + S - 1), measured in the test.

Differentiable end-to-end (ppermute transposes to the reverse permute), so
the same primitive serves pipeline-parallel training.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["gpipe"]


def gpipe(
    stage_fn: Callable,        # (stage_params, x (mb, ...)) -> (mb, ...)
    stage_params: Any,         # pytree stacked (S, ...) — stage axis first
    x_microbatches: jax.Array, # (M, mb, ...)
    mesh,
    axis: str = "model",
):
    """Run x through S pipeline stages. Returns (M, mb, ...)."""
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    T = M + S - 1                          # schedule length incl. bubbles
    perm = [(i, (i + 1) % S) for i in range(S)]

    def local(params_loc, x_all):
        # params_loc: (1, ...) — this shard's stage; x_all: (M, mb, ...)
        p = jax.tree.map(lambda a: a[0], params_loc)
        idx = jax.lax.axis_index(axis)
        outs0 = jnp.zeros_like(x_all)
        carry0 = jnp.zeros_like(x_all[0])

        def step(state, t):
            outs, carry = state
            # stage 0 ingests microbatch t (clamped; masked past M)
            mb_idx = jnp.clip(t, 0, M - 1)
            x0 = jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0, keepdims=False)
            x_in = jnp.where((idx == 0) & (t < M), x0, carry)
            y = stage_fn(p, x_in)
            # last stage emits microbatch t - (S - 1)
            out_t = t - (S - 1)
            write = (idx == S - 1) & (out_t >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(out_t, 0, M - 1), 0
            )
            outs = jnp.where(write, upd, outs)
            carry = jax.lax.ppermute(y, axis, perm)
            return (outs, carry), None

        (outs, _), _ = jax.lax.scan(step, (outs0, carry0), jnp.arange(T))
        return outs[None]                  # (1, M, mb, ...) stage-stacked

    other_axes = [a for a in mesh.axis_names if a != axis]
    stacked = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(*([None] * x_microbatches.ndim))),
        out_specs=P(axis),
        check_vma=False,
    )(stage_params, x_microbatches)
    return stacked[S - 1]                  # the last stage's outputs
