"""Logical-axis sharding rules: param/cache pytrees -> PartitionSpecs.

Strategy (MaxText-style, name-based):
* TP over the 'model' mesh axis for head/ff/expert/vocab axes — applied only
  when the tensor axis is *divisible-by-design* (heads % model == 0 etc.);
  otherwise that tensor falls back to replication over 'model' and relies on
  FSDP.  This is what makes one fixed (pod, data, model) production mesh
  serve 10 heterogeneous architectures.
* FSDP over 'data' (cfg.fsdp): params additionally sharded on their
  d_model-like axis; pjit inserts the all-gather at use and the
  reduce-scatter on the gradient — ZeRO-3 for free.  Multi-pod keeps FSDP
  *within* a pod (axis 'data'), so gradient sync across pods is a pure
  all-reduce (hierarchical: RS within pod, AR across, AG within).
* Stacked-layer leading axes (from scan-over-layers) are never sharded.

Cache rules (decode): batch -> ('pod','data') when divisible; kv-heads ->
'model' when divisible, else the sequence axis -> 'model' (distributed-
softmax attention), else replicate.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = [
    "param_specs", "param_shardings", "cache_shardings", "batch_shardings",
    "tree_shardings",
]

Pytree = Any


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _leaf_spec(path_names, leaf, cfg: ModelConfig, mesh: Mesh,
               serving: bool = False):
    """Base spec for the trailing dims of one parameter; leading stack dims
    are filled with None.  serving=True places SSM weights tensor-parallel
    (servers have no backward stacks, so the Z1 replicated+seq-sharded
    layout only costs them; see §Perf Z1/serving note)."""
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""
    msize = _axsize(mesh, "model")
    F = "data" if cfg.fsdp else None  # fsdp axis

    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    heads_tp = _div(H, msize) and _div(K, msize)
    # §Perf Z1 (CONFIRMED): sequence-sharded SSM activations with REPLICATED
    # (FSDP-only) mamba weights beat both Megatron TP (baseline, 17.2 s of
    # collectives) and Megatron-SP TP weights (Z2, REFUTED — see ssm.py).
    # TP weight sharding is only used when seq-parallel mode is off.
    import os

    ssm_tp = bool(
        (serving or not cfg.ssm_seq_parallel
         or os.environ.get("REPRO_SSM_TP") == "1")
        and cfg.ssm_heads
        and _div(cfg.ssm_heads, msize)
        and _div(cfg.d_inner, msize)
    )
    ff_tp = _div(cfg.d_ff, msize) if cfg.d_ff else False
    Mh = "model" if heads_tp else None
    Ms = "model" if ssm_tp else None
    Ms_dt = Ms  # dt/A/D are per-head vectors; sharded iff heads are
    Mf = "model" if ff_tp else None
    # §Perf Z3 (REFUTED): folding 'model' into the FSDP axis for the
    # model-replicated SSM weights (full-mesh ZeRO) regressed zamba2 train
    # collectives 7.46 s -> 8.40 s — XLA turned the wider gathers into extra
    # all-reduces rather than reduce-scatters.  Kept behind REPRO_SSM_ZERO_FULL.
    import os as _os

    ssm_zero_full = (
        _os.environ.get("REPRO_SSM_ZERO_FULL") == "1"
        and cfg.ssm_seq_parallel and not ssm_tp and cfg.fsdp
    )
    Fs = ("data", "model") if ssm_zero_full else F    # input-dim axis
    Fs2 = ("data", "model") if ssm_zero_full else F   # output-dim axis (out_proj)

    table = {
        # embeddings / head
        "tok_emb": ("model", F),
        "lm_head": ("model", F),
        "dec_pos": (None, None),
        # attention
        "wq": (F, Mh), "wk": (F, Mh), "wv": (F, Mh),
        "bq": (Mh,), "bk": (Mh,), "bv": (Mh,),
        "wo": (Mh, F),
        # MLA
        "wq_a": (F, None), "wq_b": (None, Mh),
        "wkv_a": (F, None), "wkv_b": (None, Mh),
        "q_ln": (None,), "kv_ln": (None,),
        # dense MLP (parent 'mlp') vs expert MLP (parent 'moe', E leading)
        "wg": ("model", F, None) if parent == "moe" else (F, Mf),
        "wu": ("model", F, None) if parent == "moe" else (F, Mf),
        "wd": ("model", None, F) if parent == "moe" else (Mf, F),
        "w1": (F, Mf), "b1": (Mf,), "w2": (Mf, F), "b2": (None,),
        "router": (None, None),
        "shared_wg": (F, Mf or None), "shared_wu": (F, Mf or None),
        "shared_wd": (Mf or None, F),
        # mamba (B/C projections stay replicated: 2gn channels are tiny and
        # every head shard needs the full B/C — see ssm._project).
        # §Perf Z3: with seq-parallel SSM the weights are model-replicated,
        # so ZeRO-3 them over the FULL mesh (('data','model') on d_model):
        # grad sync becomes a reduce-scatter instead of an all-reduce over
        # 'model', and optimizer shards shrink by model_size.
        "in_z": (Fs, Ms), "in_x": (Fs, Ms), "in_BC": (Fs, None), "in_dt": (Fs, Ms_dt),
        "conv_x_w": (None, Ms), "conv_x_b": (Ms,),
        "conv_BC_w": (None, None), "conv_BC_b": (None,),
        "A_log": (Ms_dt,), "D": (Ms_dt,), "dt_bias": (Ms_dt,),
        "norm_w": (Ms,), "out_proj": (Ms, Fs2),
        # norms / gates / mtp
        "ln1": (None,), "ln2": (None,), "ln3": (None,),
        "final_norm": (None,), "w": (None,), "b": (None,),
        "gate_attn": (None,), "gate_mlp": (None,),
        "mtp_proj": (F, None), "mtp_norm_h": (None,), "mtp_norm_e": (None,),
    }
    if name not in table:
        raise KeyError(f"no sharding rule for param {'/'.join(path_names)}")
    base = table[name]
    # moe shared experts: ff width = n_shared * d_expert; check divisibility
    if name.startswith("shared_w"):
        fs = cfg.n_shared_experts * cfg.d_expert
        if not _div(fs, msize):
            base = tuple(None if a == "model" else a for a in base)
    # expert tensors: expert-parallel only when E % model == 0
    if parent == "moe" and name in ("wg", "wu", "wd") and not _div(cfg.n_experts, msize):
        base = tuple(None if a == "model" else a for a in base)
    n_lead = leaf.ndim - len(base)
    assert n_lead >= 0, (path_names, leaf.shape, base)
    # never shard an axis the shape can't divide (pjit requires divisibility;
    # odd vocabs like 50280/51865 fall back to replicated embeddings)
    final = []
    for i, a in enumerate((None,) * n_lead + tuple(base)):
        if a is None:
            final.append(None)
            continue
        ax_names = a if isinstance(a, tuple) else (a,)
        size = int(np.prod([_axsize(mesh, x) for x in ax_names]))
        final.append(a if _div(leaf.shape[i], size) else None)
    return P(*final)


def _path_names(path):
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
    return out


def param_specs(params: Pytree, cfg: ModelConfig, mesh: Mesh,
                serving: bool = False) -> Pytree:
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        _leaf_spec(_path_names(path), leaf, cfg, mesh, serving=serving)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Pytree, cfg: ModelConfig, mesh: Mesh,
                    serving: bool = False) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params, cfg, mesh, serving=serving),
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_shardings(opt_state: Pytree, params: Pytree, cfg, mesh) -> Pytree:
    """Optimizer moments inherit the parameter sharding; step is replicated."""
    pspecs = param_specs(params, cfg, mesh)
    mu = jax.tree.map(
        lambda s: {"m": NamedSharding(mesh, s), "v": NamedSharding(mesh, s)},
        pspecs, is_leaf=lambda x: isinstance(x, P),
    )
    return {"mu": mu, "step": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------


def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_shardings(batch: Pytree, mesh: Mesh) -> Pytree:
    """tokens/frames/img: batch dim over (pod, data); scalars replicated."""
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if not _div(leaf.shape[0], dp_size):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *(None,) * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def cache_shardings(cache: Pytree, cfg: ModelConfig, mesh: Mesh) -> Pytree:
    """Decode caches: batch over (pod,data); then kv-heads over model when
    divisible, else the sequence axis over model (distributed attention),
    else replicated.  Cache layouts (see models/*.init_cache):
      attention k/v     (..., B, S, K, Dh)
      mla latent        (..., B, S, width)
      ssm conv/state    (..., B, K-1, ch) / (..., B, h, p, n)
    Identified positionally: the batch axis is the first axis of size B.
    """
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    msize = _axsize(mesh, "model")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)

    out = []
    for path, leaf in flat:
        names = _path_names(path)
        name = names[-1]
        spec = [None] * leaf.ndim
        # find batch axis: caches are (stack..., B, ...) — locate by name
        if name in ("k", "v", "attn_k", "attn_v", "self_k", "self_v",
                    "cross_k", "cross_v", "img_k", "img_v"):
            # (..., B, S, K, Dh)
            bax, sax, kax = leaf.ndim - 4, leaf.ndim - 3, leaf.ndim - 2
            if _div(leaf.shape[bax], dp_size):
                spec[bax] = dp
            if _div(leaf.shape[kax], msize):
                spec[kax] = "model"
            elif _div(leaf.shape[sax], msize):
                spec[sax] = "model"
        elif name.startswith("latent"):
            bax, sax = leaf.ndim - 3, leaf.ndim - 2
            if _div(leaf.shape[bax], dp_size):
                spec[bax] = dp
            if _div(leaf.shape[sax], msize):
                spec[sax] = "model"
        elif name.startswith("conv"):
            bax, cax = leaf.ndim - 3, leaf.ndim - 1
            if _div(leaf.shape[bax], dp_size):
                spec[bax] = dp
            if _div(leaf.shape[cax], msize):
                spec[cax] = "model"
        elif name.startswith("ssm"):
            bax, hax = leaf.ndim - 4, leaf.ndim - 3
            if _div(leaf.shape[bax], dp_size):
                spec[bax] = dp
            if _div(leaf.shape[hax], msize):
                spec[hax] = "model"
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shardings(tree: Pytree, mesh: Mesh, spec=P()) -> Pytree:
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), tree)
