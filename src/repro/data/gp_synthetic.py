"""Synthetic GP regression datasets — paper §3, Eq. 21.

    y = sum_{i=1..p} cos(x_i) + nu,   nu ~ N(0, sigma_n^2)

The paper's bash script generates these with increasing n and p at fixed
N = 10^4; ``make_gp_dataset`` is the same generator as a pure function
(deterministic in seed), used by the benchmarks and examples.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["make_gp_dataset"]


def make_gp_dataset(
    N: int,
    p: int,
    *,
    noise: float = 0.05,
    lo: float = -1.0,
    hi: float = 1.0,
    seed: int = 0,
    test_frac: float = 0.1,
):
    """Returns (X, y, Xs, ys): train/test splits of the Eq. 21 function."""
    rng = np.random.default_rng(seed)
    n_test = max(1, int(N * test_frac))
    X_all = rng.uniform(lo, hi, size=(N + n_test, p)).astype(np.float32)
    f = np.sum(np.cos(X_all), axis=1)
    y_all = (f + noise * rng.standard_normal(N + n_test)).astype(np.float32)
    X, Xs = X_all[:N], X_all[N:]
    y, ys = y_all[:N], y_all[N:]
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(Xs), jnp.asarray(ys)
