"""Synthetic GP regression datasets — paper §3, Eq. 21.

    y = sum_{i=1..p} cos(x_i) + nu,   nu ~ N(0, sigma_n^2)

The paper's bash script generates these with increasing n and p at fixed
N = 10^4; ``make_gp_dataset`` is the same generator as a pure function
(deterministic in seed), used by the benchmarks and examples.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["make_clustered_dataset", "make_gp_dataset"]


def make_gp_dataset(
    N: int,
    p: int,
    *,
    noise: float = 0.05,
    lo: float = -1.0,
    hi: float = 1.0,
    seed: int = 0,
    test_frac: float = 0.1,
):
    """Returns (X, y, Xs, ys): train/test splits of the Eq. 21 function."""
    rng = np.random.default_rng(seed)
    n_test = max(1, int(N * test_frac))
    X_all = rng.uniform(lo, hi, size=(N + n_test, p)).astype(np.float32)
    f = np.sum(np.cos(X_all), axis=1)
    y_all = (f + noise * rng.standard_normal(N + n_test)).astype(np.float32)
    X, Xs = X_all[:N], X_all[N:]
    y, ys = y_all[:N], y_all[N:]
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(Xs), jnp.asarray(ys)


def make_clustered_dataset(
    N: int,
    *,
    n_clusters: int = 12,
    spread: float = 0.35,
    extent: float = 4.0,
    length_scale: float = 0.3,
    n_bumps: int = 60,
    noise: float = 0.05,
    seed: int = 0,
    test_frac: float = 0.1,
):
    """Clustered 2-D spatial regression — the regime Vecchia is built for.

    Inputs are drawn around ``n_clusters`` random centers on the wide
    ``[-extent, extent]^2`` domain (Gaussian spread per cluster), so the
    data has LOCAL structure with big empty gaps between clusters — global
    basis expansions must spend capacity on the gaps while nearest-neighbor
    conditioning does not.  Targets come from a fixed sum of ``n_bumps``
    random short-length-scale SE bumps (an explicit sample-path surrogate:
    smooth, stationary-ish, and O(N * n_bumps) to evaluate, so it scales to
    N = 10^4+ without any O(N^3) GP sampling) plus observation noise.

    Test points are drawn around the SAME centers (interpolation within
    clusters, the spatial-statistics task), deterministic in ``seed``.
    Returns ``(X, y, Xs, ys)`` like :func:`make_gp_dataset`.
    """
    rng = np.random.default_rng(seed)
    n_test = max(1, int(N * test_frac))
    n_all = N + n_test
    centers = rng.uniform(-extent, extent, size=(n_clusters, 2))
    which = rng.integers(0, n_clusters, size=n_all)
    X_all = (
        centers[which] + spread * rng.standard_normal((n_all, 2))
    ).astype(np.float32)
    # fixed random bump field: f(x) = sum_j a_j exp(-|x - c_j|^2 / (2 l^2))
    bump_c = rng.uniform(-extent - 1.0, extent + 1.0, size=(n_bumps, 2))
    bump_a = rng.standard_normal(n_bumps)
    d2 = np.sum(
        (X_all[:, None, :] - bump_c[None, :, :]) ** 2, axis=-1
    )
    f = (np.exp(-d2 / (2.0 * length_scale**2)) @ bump_a).astype(np.float32)
    y_all = (f + noise * rng.standard_normal(n_all)).astype(np.float32)
    X, Xs = X_all[:N], X_all[N:]
    y, ys = y_all[:N], y_all[N:]
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(Xs), jnp.asarray(ys)
