"""Deterministic synthetic LM corpus with restart-safe batching.

Batches are a pure function of (seed, step): after a crash/preemption the
loop resumes from the checkpointed step and sees exactly the token stream it
would have seen — data-pipeline statelessness is what makes checkpoint/
restart exact (tested in test_runtime.py).

The corpus is a learnable order-2 Markov chain over the vocabulary (not
uniform noise): loss decreases measurably within a few hundred steps, which
examples/train_lm.py relies on.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    markov_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        s = self.markov_states
        # sparse-ish transition structure projected onto the vocab
        self._trans = rng.dirichlet(np.full(s, 0.25), size=s).astype(np.float32)
        self._cum = np.cumsum(self._trans, axis=1)
        self._emit = rng.integers(0, self.vocab, size=s).astype(np.int64)

    def batch(self, step: int, extras: dict | None = None) -> dict:
        """tokens (global_batch, seq) int32, deterministic in (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        B, S, s = self.global_batch, self.seq, self.markov_states
        u = rng.random((B, S), dtype=np.float32)
        state = rng.integers(0, s, size=B)
        toks = np.empty((B, S), np.int64)
        for t in range(S):
            toks[:, t] = self._emit[state]
            state = (self._cum[state] < u[:, t : t + 1]).sum(axis=1).clip(0, s - 1)
        out = {"tokens": jnp.asarray(toks.astype(np.int32))}
        if extras:
            out.update(extras)
        return out
