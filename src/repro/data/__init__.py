"""Data pipelines: paper Eq. 21 GP datasets + deterministic LM token streams."""
from . import gp_synthetic, lm_synthetic
from .gp_synthetic import make_gp_dataset
from .lm_synthetic import TokenStream
