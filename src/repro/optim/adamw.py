"""AdamW optimizer as a pure-JAX pytree transform (no optax dependency).

Production details for pod scale:
* configurable moment dtype (``state_dtype=bf16`` halves optimizer HBM for
  >100B-param models; master math always runs in f32),
* global-norm gradient clipping,
* decoupled weight decay with parameter masking (no decay on norms/biases),
* works on arbitrary pytrees; optimizer state inherits parameter sharding
  (same tree structure -> same PartitionSpecs), which is what makes
  ZeRO-style sharded optimizer state free under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init", "apply_updates", "global_norm"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[Any] = None   # None -> same as param dtype
    # predicate(path, leaf) -> apply weight decay?  default: ndim >= 2
    decay_mask: Optional[Callable] = None


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def init(params: Pytree, cfg: AdamWConfig) -> Pytree:
    def make(p):
        dt = cfg.state_dtype or p.dtype
        return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

    return {"mu": jax.tree.map(make, params), "step": jnp.zeros((), jnp.int32)}


def _lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    if callable(cfg.lr):
        return jnp.asarray(cfg.lr(step), jnp.float32)
    return jnp.asarray(cfg.lr, jnp.float32)


def apply_updates(params: Pytree, grads: Pytree, state: Pytree, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = _lr_at(cfg, step)
    c1 = 1.0 - cfg.b1**step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2**step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["mu"])

    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        g32 = g.astype(jnp.float32)
        m = s["m"].astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v = s["v"].astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1.0 - cfg.b2)
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        if cfg.decay_mask is not None:
            decay = cfg.weight_decay if cfg.decay_mask(p) else 0.0
        p32 = p.astype(jnp.float32) - lr * (upd + decay * p.astype(jnp.float32))
        new_p.append(p32.astype(p.dtype))
        sd = s["m"].dtype
        new_s.append({"m": m.astype(sd), "v": v.astype(sd)})

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {"mu": jax.tree_util.tree_unflatten(treedef, new_s), "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
