"""Optimizers, schedules, and distributed-gradient utilities.

``gp_hyperopt`` is the fleet-scale batched GP hyperparameter optimizer
(the (B tenants x R restarts) lane engine behind ``GP.optimize`` and
``GPBank.optimize``).
"""
from . import adamw, schedules
from .adamw import AdamWConfig, apply_updates, global_norm, init
from .schedules import constant, warmup_cosine, warmup_linear
from . import gp_hyperopt
from .gp_hyperopt import HyperoptResult, optimize_fleet, optimize_restarts
