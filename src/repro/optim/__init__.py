"""Optimizers, schedules, and distributed-gradient utilities."""
from . import adamw, schedules
from .adamw import AdamWConfig, apply_updates, global_norm, init
from .schedules import constant, warmup_cosine, warmup_linear
