"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_linear", "constant"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_linear(lr: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(1, warmup)
        frac = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        return lr * jnp.where(s < warmup, warm, 1.0 - (1.0 - floor) * frac)

    return f


def warmup_cosine(lr: float, warmup: int, total: int, floor_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(1, warmup)
        frac = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor_frac + (1.0 - floor_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * jnp.where(s < warmup, warm, cos)

    return f
