"""Fleet-scale batched GP hyperparameter optimization — the lane engine.

The paper's cheap-posterior claim only pays off once hyperparameters are
chosen, and its regime — many small decomposed GPs — is exactly where a
Python loop of per-model optimizers is dominated by per-step dispatch.
This module optimizes the log-space NLML of **every tenant and every
random restart at once**: one jitted AdamW step over a ``(B, R)`` *lane*
axis, where lane ``(t, r)`` is restart ``r`` of tenant ``t``.

Anatomy of a step (``_lane_step``):

* the objective is the masked NLML of ``core.fagp`` (``fagp._nlml_core``):
  the restart axis is **vmapped** (hyperparameters mapped, data shared)
  and the tenant axis is a **compiled scan** (``lax.map``) whose body is
  the identical per-tenant program — still ONE executable and one
  dispatch per step for the whole fleet, but, unlike a tenant-axis vmap,
  the per-tenant f32 arithmetic is bit-identical to a single-tenant run
  (batched lowering reorders reductions, which would drift trajectories
  past any usable parity bound; the scan makes the <= 1e-5 fleet-vs-loop
  gate assertable by construction).  The moment accumulation inside
  dispatches through the backend registry's ``moments`` hooks, so the
  optimization loop never materializes an N x M feature matrix on either
  backend (custom-VJP streamed backward; pinned by the jaxpr sweep in
  tests/test_gp_hyperopt.py);
* parameters are log-space leaves ``{log_eps, log_rho, log_noise}``
  (positivity by construction; the RFF spectral draws ``omega`` stay
  frozen — they are structure, not hyperparameters), stepped by
  ``repro.optim`` AdamW;
* **convergence masks**: a lane whose NLML improved by less than ``tol``
  freezes — its parameters AND its optimizer moments are carried through
  ``jnp.where`` unchanged, so frozen lanes stop moving bit-exactly while
  the step stays ONE fixed-shape executable (no recompiles as lanes
  converge; pinned via jit cache-miss counts);
* per-slot row masks express ragged per-tenant N on one fixed (B, N, p)
  stack, exactly as in ``GPBank.fit``.

Restart jitter is keyed by ``(seed, restart)`` only — every tenant sees
the SAME R perturbations of its init — so a loop of single-tenant runs
with the same seed reproduces the fleet's lanes exactly (the parity gate
in benchmarks/gp_hyperopt.py compares the two to <= 1e-5).

``optimize_fleet`` drives the loop and selects the best restart per tenant
by final NLML; ``optimize_restarts`` is the single-model wrapper that
``GP.optimize`` delegates to; ``GPBank.optimize`` refits the winners back
into its stacked state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fagp
from repro.optim import adamw

__all__ = ["HyperoptResult", "optimize_fleet", "optimize_restarts"]

_FIELDS = ("log_eps", "log_rho", "log_noise")


@dataclasses.dataclass(frozen=True)
class HyperoptResult:
    """Per-tenant winners plus the full lane picture.

    eps/rho (B, p) and noise (B,) are the best restart's hyperparameters in
    natural space; ``nlml`` (B,) is that lane's final NLML per data row
    (the selection criterion); ``lane_nlml`` (B, R) keeps every restart's
    final value; ``frozen`` (B, R) marks lanes the convergence mask froze
    before the step budget ran out.
    """

    eps: jax.Array            # (B, p)
    rho: jax.Array            # (B, p)
    noise: jax.Array          # (B,)
    nlml: jax.Array           # (B,)   best lane's final NLML / row
    lane_nlml: jax.Array      # (B, R) every lane's final NLML / row
    best_restart: jax.Array   # (B,)
    frozen: np.ndarray        # (B, R)
    steps_run: int

    def spec_for(self, spec, t: int = 0):
        """The input spec with tenant ``t``'s learned hyperparameters."""
        return spec.replace(
            eps=self.eps[t], rho=self.rho[t], noise=self.noise[t]
        )


def _hp_to_spec(spec, hp):
    return spec.replace(
        eps=jnp.exp(hp["log_eps"]),
        rho=jnp.exp(hp["log_rho"]),
        noise=jnp.exp(hp["log_noise"]),
    )


def _init_lanes(spec, B: int, R: int, seed: int, jitter: float,
                init: Optional[dict]):
    """(B, R)-lane log-space parameter stack.  ``init`` optionally supplies
    per-tenant natural-space starting points {eps (B,p), rho (B,p),
    noise (B,)} (a heterogeneous bank re-optimizing from its current
    hyperparameters); otherwise every tenant starts from the spec.

    Restart 0 is always the unperturbed init; restarts 1..R-1 add
    N(0, jitter^2) log-space noise keyed by (seed, field, restart) ONLY —
    identical draws for every tenant, so single-tenant reruns with the
    same seed land on identical lanes."""
    if init is None:
        base = {
            "log_eps": jnp.broadcast_to(jnp.log(spec.eps),
                                        (B,) + spec.eps.shape),
            "log_rho": jnp.broadcast_to(jnp.log(spec.rho),
                                        (B,) + spec.rho.shape),
            "log_noise": jnp.broadcast_to(
                jnp.log(jnp.asarray(spec.noise, jnp.float32)), (B,)
            ),
        }
    else:
        base = {
            "log_eps": jnp.log(jnp.asarray(init["eps"], jnp.float32)),
            "log_rho": jnp.log(jnp.asarray(init["rho"], jnp.float32)),
            "log_noise": jnp.log(jnp.asarray(init["noise"], jnp.float32)),
        }
        for f, nd in (("log_eps", 2), ("log_rho", 2), ("log_noise", 1)):
            if base[f].ndim != nd or base[f].shape[0] != B:
                raise ValueError(
                    f"init[{f[4:]!r}] must have leading dim B={B} and "
                    f"ndim {nd}, got {base[f].shape}"
                )
    out = {}
    for f, key in zip(_FIELDS, jax.random.split(jax.random.PRNGKey(seed), 3)):
        leaf = base[f].astype(jnp.float32)            # (B,) + shape
        tiled = jnp.broadcast_to(leaf[:, None], (B, R) + leaf.shape[1:])
        if R > 1 and jitter:
            noise = jax.random.normal(
                key, (R,) + leaf.shape[1:], jnp.float32
            ) * jitter
            noise = noise.at[0].set(0.0)
            tiled = tiled + noise[None]
        out[f] = tiled
    return out


def _where_lanes(cond, a, b):
    """Select a/b per lane: cond (B, R) broadcast over trailing axes."""
    return jnp.where(cond.reshape(cond.shape + (1,) * (a.ndim - 2)), a, b)


_LANE_CLIP = 10.0


def _clip_per_lane(grads, clip: float):
    """Per-LANE gradient-norm clipping.  AdamW's built-in clip uses the
    global norm of the whole pytree, which would couple every tenant and
    restart through one shared scale — a B-tenant fleet would then follow a
    different trajectory than B single-tenant runs.  Clipping each (t, r)
    lane by its own norm keeps lanes exactly independent (the fleet-vs-loop
    parity gate depends on this)."""
    sq = sum(
        jnp.sum(
            jnp.square(g.astype(jnp.float32)),
            axis=tuple(range(2, g.ndim)),
        )
        for g in grads.values()
    )                                                   # (B, R)
    scale = jnp.minimum(1.0, clip / (jnp.sqrt(sq) + 1e-9))
    return {
        f: g * scale.reshape(scale.shape + (1,) * (g.ndim - 2))
        for f, g in grads.items()
    }


def _lane_loss(hp, X, y, mask, spec, idx):
    """One lane's objective: masked NLML per data row at exp(hp).  ``idx``
    rides along purely so the traced table is shared; the masked core
    re-derives it from the spec's static metadata."""
    del idx  # derived inside _nlml_core from the spec's static metadata
    sp = _hp_to_spec(spec, hp)
    return fagp._nlml_core(X, y, sp, mask) / jnp.maximum(jnp.sum(mask), 1.0)


@partial(jax.jit, static_argnames=("ocfg",))
def _lane_step(hp, ostate, frozen, prev, Xb, yb, maskb, spec, idx, tol,
               ocfg):
    """One AdamW step over every (tenant, restart) lane.

    Returns (hp, ostate, frozen, prev, vals) where ``vals`` (B, R) is the
    loss at the INPUT parameters.  A lane freezes when its improvement
    since the previous step falls below ``tol``; frozen lanes carry their
    parameters and optimizer moments through unchanged (bit-exact), and
    the executable is keyed only on the stack shapes — convergence
    patterns, masks and data churn never recompile it."""
    vg = jax.value_and_grad(_lane_loss)
    per_restart = jax.vmap(vg, in_axes=(0, None, None, None, None, None))
    vals, grads = jax.lax.map(
        lambda args: per_restart(*args, spec, idx), (hp, Xb, yb, maskb)
    )
    frozen = frozen | (prev - vals < tol)
    grads = _clip_per_lane(grads, _LANE_CLIP)
    new_hp, new_ostate, _ = adamw.apply_updates(hp, grads, ostate, ocfg)
    hp = {f: _where_lanes(frozen, hp[f], new_hp[f]) for f in hp}
    mu = {
        f: {
            k: _where_lanes(frozen, ostate["mu"][f][k], new_ostate["mu"][f][k])
            for k in ("m", "v")
        }
        for f in hp
    }
    ostate = {"mu": mu, "step": new_ostate["step"]}
    prev = jnp.where(frozen, prev, vals)
    return hp, ostate, frozen, prev, vals


@jax.jit
def _lane_values(hp, Xb, yb, maskb, spec, idx):
    """Final per-lane NLML/row at the CURRENT parameters (the best-restart
    selection criterion — ``_lane_step``'s vals lag one update behind)."""
    per_restart = jax.vmap(_lane_loss, in_axes=(0, None, None, None, None,
                                                None))
    return jax.lax.map(
        lambda args: per_restart(*args, spec, idx), (hp, Xb, yb, maskb)
    )


def _compose_obs_callback(user_cb, metrics, tracer):
    """Wrap the optimize_fleet progress-callback contract with telemetry:
    the observer fires first (round counter + step/best-NLML gauges + a
    ``hyperopt_progress`` instant event), then the user's callback, with
    exactly the ``(step, vals, hp)`` arguments the contract specifies."""
    counter = gauge_step = gauge_best = None
    if metrics is not None:
        counter = metrics.counter(
            "hyperopt_rounds_total", "progress-callback firings")
        gauge_step = metrics.gauge(
            "hyperopt_step", "current optimizer step")
        gauge_best = metrics.gauge(
            "hyperopt_best_nlml", "best lane NLML/row at the last firing")

    def cb(step, vals, hp):
        if counter is not None:
            counter.inc()
            gauge_step.set(step)
            gauge_best.set(float(np.min(vals)))
        if tracer is not None:
            tracer.instant("hyperopt_progress", step=int(step),
                           best_nlml=float(np.min(vals)))
        if user_cb is not None:
            user_cb(step, vals, hp)

    return cb


def optimize_fleet(
    Xb: jax.Array,
    yb: jax.Array,
    spec,
    *,
    mask: Optional[jax.Array] = None,
    restarts: int = 4,
    steps: int = 100,
    lr: float = 5e-2,
    tol: Optional[float] = None,
    jitter: float = 0.3,
    seed: int = 0,
    init: Optional[dict] = None,
    callback: Optional[Callable] = None,
    metrics=None,
    tracer=None,
) -> HyperoptResult:
    """Batched NLML hyperparameter learning for B independent tenants with
    R random restarts each — every lane in one compiled AdamW step.

    Xb (B, N, p), yb (B, N) (or (B, N, T) multi-output), mask (B, N) row
    validity for ragged per-tenant N.  ``tol`` (None = never) freezes lanes
    whose per-step NLML improvement drops below it; the loop exits early
    once every lane froze.  ``callback(step, vals, hp)`` fires every ~10%
    with the (B, R) loss snapshot and the raw log-space lane parameters.

    ``metrics`` / ``tracer`` (``repro.obs``) report per-round progress
    THROUGH that same callback contract — an internal observer composed
    in front of any user callback records a round counter, the current
    step and best lane NLML as gauges, and a ``hyperopt_progress``
    instant trace event per firing.  The optimization loop itself is
    untouched (no extra device syncs: the observer reads the ``vals``
    snapshot the callback already materializes).

    Returns a :class:`HyperoptResult` with the best restart per tenant
    selected by final NLML.
    """
    if metrics is not None or tracer is not None:
        callback = _compose_obs_callback(callback, metrics, tracer)
    Xb = jnp.asarray(Xb)
    yb = jnp.asarray(yb)
    if Xb.ndim != 3 or yb.ndim not in (2, 3) or yb.shape[:2] != Xb.shape[:2]:
        raise ValueError(
            f"optimize_fleet wants Xb (B, N, p) and yb (B, N[, T]); got "
            f"{Xb.shape} and {yb.shape}"
        )
    B, N, p = Xb.shape
    if restarts < 1 or steps < 1:
        raise ValueError("restarts and steps must be >= 1")
    fagp._check_p(spec, p)
    fagp._check_backend_support(spec)
    if mask is None:
        mask = jnp.ones((B, N), jnp.float32)
    else:
        mask = jnp.asarray(mask).astype(jnp.float32)
        if mask.shape != (B, N):
            raise ValueError(
                f"mask must be (B, N) = {(B, N)}, got {mask.shape}"
            )
    # small tenants: do not pad each slot's few rows up to the serving block
    spec = spec.replace(block_rows=min(spec.block_rows, max(1, N)))
    idx = jnp.asarray(spec.indices(p))

    # XLA inlines a LENGTH-1 tenant scan into its consumers, changing
    # fusion and therefore f32 rounding relative to the same tenant inside
    # a longer fleet; pad single-tenant runs to a length-2 scan (duplicate
    # tenant, sliced off below) so a lone GP.optimize run is bit-identical
    # to any fleet containing it — the fleet-vs-loop parity gate rests on
    # this invariance.
    B_run = B
    if B == 1:
        B_run = 2
        Xb = jnp.concatenate([Xb, Xb])
        yb = jnp.concatenate([yb, yb])
        mask = jnp.concatenate([mask, mask])
        if init is not None:
            init = {k: jnp.concatenate([jnp.asarray(v)] * 2) for k, v in
                    init.items()}

    hp = _init_lanes(spec, B_run, restarts, seed, jitter, init)
    # clip_norm=None: clipping happens per lane inside _lane_step (the
    # global-norm clip would couple every lane through one shared scale)
    ocfg = adamw.AdamWConfig(lr=lr, weight_decay=0.0, clip_norm=None)
    ostate = adamw.init(hp, ocfg)
    frozen = jnp.zeros((B_run, restarts), bool)
    prev = jnp.full((B_run, restarts), jnp.inf, jnp.float32)
    tol_f = jnp.float32(-jnp.inf if tol is None else tol)

    every = max(1, steps // 10)
    steps_run = steps
    for step in range(steps):
        hp, ostate, frozen, prev, vals = _lane_step(
            hp, ostate, frozen, prev, Xb, yb, mask, spec, idx, tol_f, ocfg
        )
        if callback is not None and (step % every == 0 or step == steps - 1):
            callback(step, np.asarray(vals)[:B], hp)
        if tol is not None and bool(jnp.all(frozen)):
            steps_run = step + 1
            break

    final = _lane_values(hp, Xb, yb, mask, spec, idx)      # (B_run, R)
    best = jnp.argmin(final, axis=1)                       # (B_run,)
    rows = jnp.arange(B_run)
    pick = lambda f: hp[f][rows, best]
    return HyperoptResult(
        eps=jnp.exp(pick("log_eps"))[:B],
        rho=jnp.exp(pick("log_rho"))[:B],
        noise=jnp.exp(pick("log_noise"))[:B],
        nlml=final[rows, best][:B],
        lane_nlml=final[:B],
        best_restart=best[:B],
        frozen=np.asarray(frozen)[:B],
        steps_run=steps_run,
    )


def optimize_restarts(
    X: jax.Array,
    y: jax.Array,
    spec,
    *,
    restarts: int = 1,
    steps: int = 100,
    lr: float = 5e-2,
    tol: Optional[float] = None,
    jitter: float = 0.3,
    seed: int = 0,
    callback: Optional[Callable] = None,
) -> HyperoptResult:
    """Single-model wrapper over :func:`optimize_fleet` (a B=1 fleet):
    multi-start gradient NLML learning for one dataset.  ``GP.optimize``
    delegates here; the result keeps its leading B=1 axis
    (``result.spec_for(spec)`` extracts the winner)."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    if X.ndim != 2:
        raise ValueError(f"optimize_restarts wants X (N, p), got {X.shape}")
    return optimize_fleet(
        X[None], y[None], spec, restarts=restarts, steps=steps, lr=lr,
        tol=tol, jitter=jitter, seed=seed, callback=callback,
    )
