"""Beyond-paper bridge: the paper's Mercer kernel expansion as sub-quadratic
attention (see models/mercer_attention.py and DESIGN.md §Arch-applicability).

    PYTHONPATH=src python examples/mercer_attention_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.mercer_attention import mercer_linear_attention
from repro.models.layers import gqa_attention


def main():
    rng = np.random.default_rng(0)
    B, H, D = 1, 4, 16

    def norm(x):
        n = np.linalg.norm(x, axis=-1, keepdims=True)
        return x / np.maximum(n, 1e-6)

    print(f"{'S':>7} {'softmax(flash) s':>17} {'mercer-linear s':>16} {'max|diff|':>10}")
    for S in (1024, 4096, 16384):
        q = jnp.asarray(norm(rng.standard_normal((B, S, H, D))).astype(np.float32))
        k = jnp.asarray(norm(rng.standard_normal((B, S, H, D))).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))

        f_exact = jax.jit(lambda q, k, v: gqa_attention(q, k, v, causal=True))
        f_merc = jax.jit(lambda q, k, v: mercer_linear_attention(q, k, v, causal=True))
        o1 = jax.block_until_ready(f_exact(q, k, v))
        o2 = jax.block_until_ready(f_merc(q, k, v))
        t0 = time.perf_counter(); jax.block_until_ready(f_exact(q, k, v)); t1 = time.perf_counter()
        jax.block_until_ready(f_merc(q, k, v)); t2 = time.perf_counter()
        d = float(jnp.max(jnp.abs(o1 - o2)))
        print(f"{S:>7} {t1-t0:>17.3f} {t2-t1:>16.3f} {d:>10.4f}")
    print("\nmercer-linear is O(S·M); exact attention is O(S²) — the paper's "
          "accuracy-vs-M tradeoff, applied to attention.")


if __name__ == "__main__":
    main()
