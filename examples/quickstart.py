"""Quickstart: 1-D GP regression with the Mercer-decomposed kernel (FAGP),
through the self-describing `GP` session facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import exact_gp, mercer
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset


def main():
    X, y, Xs, ys = make_gp_dataset(N=400, p=1, noise=0.05, seed=0)

    # exact GP (paper Eqs. 3-4): the O(N^3) baseline
    params = mercer.SEKernelParams.create([0.8], [2.0], noise=0.05)
    mu_e, cov_e = exact_gp.predict(exact_gp.fit(X, y, params), Xs)

    # FAGP (paper Eqs. 11-12): only an n x n solve, n = 24 eigenvalues.
    # One spec describes the whole session; it is baked into the fit.
    spec = GPSpec.create(24, eps=[0.8], rho=[2.0], noise=0.05)
    gp = GP.fit(X, y, spec)
    mu_a, var_a = gp.mean_var(Xs)

    rmse_e = float(jnp.sqrt(jnp.mean((mu_e - ys) ** 2)))
    rmse_a = float(jnp.sqrt(jnp.mean((mu_a - ys) ** 2)))
    gap = float(jnp.max(jnp.abs(mu_a - mu_e)))
    print(f"exact GP rmse:  {rmse_e:.4f}")
    print(f"FAGP rmse:      {rmse_a:.4f}   (n=24 eigenvalues, M={gp.n_features} solve)")
    print(f"max |mu_fagp - mu_exact| = {gap:.2e}")
    print(f"mean predictive std: {float(jnp.mean(jnp.sqrt(var_a))):.4f}")
    assert abs(rmse_a - rmse_e) < 5e-3


if __name__ == "__main__":
    main()
