"""Batched serving example: prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    r = serve(args.arch, smoke=True, batch=args.batch, prompt_len=48, gen=args.gen)
    print(f"prefill {r['prefill_s']*1e3:.1f}ms  "
          f"decode {r['decode_s_per_token']*1e3:.2f}ms/tok  "
          f"throughput {r['tokens_per_s']:.1f} tok/s")
    print("sample:", r["generated"][0][:12])


if __name__ == "__main__":
    main()
