"""The paper's headline case: multidimensional FAGP (p=4) where M = n^p
explodes — with the beyond-paper hyperbolic-cross fix and the Pallas
kernel backend.

    PYTHONPATH=src python examples/multidim_fagp.py
"""
import time

import numpy as np

from repro.core import fagp, mercer
from repro.data import make_gp_dataset


def main():
    p, n, N = 4, 7, 5_000
    X, y, Xs, ys = make_gp_dataset(N, p, noise=0.05, seed=3)
    params = mercer.SEKernelParams.create([0.7] * p, [2.0] * p, noise=0.05)

    for label, cfg in [
        ("full grid (paper)      ", fagp.FAGPConfig(n=n, store_train=False)),
        ("hyperbolic cross (ours)", fagp.FAGPConfig(n=n, index_set="hyperbolic_cross",
                                                    degree=2 * n, store_train=False)),
        ("hyperbolic + pallas    ", fagp.FAGPConfig(n=n, index_set="hyperbolic_cross",
                                                    degree=2 * n, store_train=False,
                                                    backend="pallas")),
    ]:
        M = cfg.indices(p).shape[0]
        t0 = time.perf_counter()
        st = fagp.fit(X, y, params, cfg)
        mu, var = fagp.predict_mean_var(st, Xs, cfg)
        mu.block_until_ready()
        dt = time.perf_counter() - t0
        rmse = float(np.sqrt(np.mean((np.asarray(mu) - np.asarray(ys)) ** 2)))
        print(f"{label}  M={M:5d}  time={dt:7.2f}s  rmse={rmse:.4f}")


if __name__ == "__main__":
    main()
