"""The paper's headline case: multidimensional FAGP (p=4) where M = n^p
explodes — with the beyond-paper hyperbolic-cross fix and the Pallas
kernel backend, through the `GP` session facade (the backend is part of
the spec, not a per-call argument).

    PYTHONPATH=src python examples/multidim_fagp.py
"""
import time

import numpy as np

from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset


def main():
    p, n, N = 4, 7, 5_000
    X, y, Xs, ys = make_gp_dataset(N, p, noise=0.05, seed=3)

    base = GPSpec.create(n, eps=[0.7] * p, rho=2.0, noise=0.05)
    for label, spec in [
        ("full grid (paper)      ", base),
        ("hyperbolic cross (ours)", base.replace(index_set="hyperbolic_cross",
                                                 degree=2 * n)),
        ("hyperbolic + pallas    ", base.replace(index_set="hyperbolic_cross",
                                                 degree=2 * n,
                                                 backend="pallas")),
    ]:
        t0 = time.perf_counter()
        gp = GP.fit(X, y, spec)
        mu, var = gp.mean_var(Xs)
        mu.block_until_ready()
        dt = time.perf_counter() - t0
        rmse = float(np.sqrt(np.mean((np.asarray(mu) - np.asarray(ys)) ** 2)))
        print(f"{label}  M={gp.n_features:5d}  time={dt:7.2f}s  rmse={rmse:.4f}")


if __name__ == "__main__":
    main()
