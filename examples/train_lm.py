"""End-to-end LM training driver: data pipeline -> sharded train step ->
fault-tolerant loop -> checkpoints.  CPU-sized by default; --scale 100m
instantiates a ~100M-param model (a few hundred steps on accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 300
"""
import argparse
import dataclasses
import tempfile

import jax

from repro import optim
from repro.configs import ARCHS
from repro.data import TokenStream
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.models.config import ModelConfig
from repro.runtime import TrainLoopConfig, train_loop


def model_100m() -> ModelConfig:
    """~100M params, llama-style (for accelerator runs)."""
    return ModelConfig(
        arch_id="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=16_384, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ARCHS["smollm-360m"].SMOKE if args.scale == "tiny" else model_100m()
    if args.scale == "tiny":
        cfg = dataclasses.replace(cfg, vocab=2048)
    model = get_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M")

    ocfg = optim.AdamWConfig(lr=optim.warmup_cosine(3e-3, 20, args.steps))
    opt_state = optim.init(params, ocfg)
    step_fn = jax.jit(make_train_step(model, ocfg), donate_argnums=(0, 1))
    stream = TokenStream(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch, seed=0)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    loop_cfg = TrainLoopConfig(steps=args.steps, ckpt_every=max(50, args.steps // 4),
                               ckpt_dir=ckpt_dir, log_every=20)
    params, opt_state, rep = train_loop(
        step_fn, params, opt_state, lambda s: stream.batch(s), loop_cfg
    )
    h = rep["history"]
    print(f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{rep['final_step']} steps; checkpoints in {ckpt_dir}")
    assert h[-1]["loss"] < h[0]["loss"]


if __name__ == "__main__":
    main()
