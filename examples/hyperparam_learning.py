"""Hyperparameter learning under the decomposed kernel — the paper's
declared future work ("A parallel implementation of the optimization
problem for hyperparameter learning is currently in development").

Gradient-based NLML minimization in (eps, rho, sigma_n), O(N M^2) per step.

    PYTHONPATH=src python examples/hyperparam_learning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fagp, mercer
from repro.data import make_gp_dataset
from repro import optim


def main():
    p, n, N = 2, 10, 1_500
    X, y, Xs, ys = make_gp_dataset(N, p, noise=0.1, seed=5)
    idx = jnp.asarray(mercer.full_grid(n, p))

    # deliberately wrong init: eps 4x too large, noise 10x too small
    hp = {"log_eps": jnp.log(jnp.full((p,), 3.0)),
          "log_rho": jnp.log(jnp.full((p,), 2.0)),
          "log_noise": jnp.log(jnp.asarray(0.01))}

    def nlml_loss(hp):
        params = mercer.SEKernelParams(
            eps=jnp.exp(hp["log_eps"]), rho=jnp.exp(hp["log_rho"]),
            noise=jnp.exp(hp["log_noise"]),
        )
        return fagp.nlml(X, y, params, idx, n) / N

    ocfg = optim.AdamWConfig(lr=5e-2, weight_decay=0.0, clip_norm=10.0)
    state = optim.init(hp, ocfg)
    loss_grad = jax.jit(jax.value_and_grad(nlml_loss))
    for step in range(120):
        loss, g = loss_grad(hp)
        hp, state, _ = optim.apply_updates(hp, g, state, ocfg)
        if step % 20 == 0:
            print(f"step {step:4d}  nlml/N={float(loss):8.4f}  "
                  f"eps={np.exp(np.asarray(hp['log_eps']))}  "
                  f"noise={float(jnp.exp(hp['log_noise'])):.4f}")

    params = mercer.SEKernelParams(
        eps=jnp.exp(hp["log_eps"]), rho=jnp.exp(hp["log_rho"]),
        noise=jnp.exp(hp["log_noise"]))
    cfg = fagp.FAGPConfig(n=n)
    mu, _ = fagp.predict_mean_var(fagp.fit(X, y, params, cfg), Xs, cfg)
    rmse = float(np.sqrt(np.mean((np.asarray(mu) - np.asarray(ys)) ** 2)))
    print(f"final test rmse: {rmse:.4f}  learned noise: "
          f"{float(jnp.exp(hp['log_noise'])):.4f} (true 0.1)")
    assert rmse < 0.15


if __name__ == "__main__":
    main()
