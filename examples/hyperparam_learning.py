"""Hyperparameter learning under the decomposed kernel — the paper's
declared future work ("A parallel implementation of the optimization
problem for hyperparameter learning is currently in development").

`GP.optimize` runs gradient-based NLML minimization in (eps, rho, sigma_n)
— the spec's hyperparameters are differentiable pytree leaves — then fits
at the learned values.  O(N M^2) per step.

    PYTHONPATH=src python examples/hyperparam_learning.py
"""
import numpy as np

from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset


def main():
    p, n, N = 2, 10, 1_500
    X, y, Xs, ys = make_gp_dataset(N, p, noise=0.1, seed=5)

    # deliberately wrong init: eps 4x too large, noise 10x too small
    spec0 = GPSpec.create(n, eps=[3.0] * p, rho=2.0, noise=0.01)

    def report(step, nlml_per_row, spec):
        print(f"step {step:4d}  nlml/N={nlml_per_row:8.4f}  "
              f"eps={np.asarray(spec.eps)}  noise={float(spec.noise):.4f}")

    gp = GP.optimize(X, y, spec0, steps=120, lr=5e-2, callback=report)

    mu, _ = gp.mean_var(Xs)
    rmse = float(np.sqrt(np.mean((np.asarray(mu) - np.asarray(ys)) ** 2)))
    print(f"final test rmse: {rmse:.4f}  learned noise: "
          f"{float(gp.spec.noise):.4f} (true 0.1)")
    assert rmse < 0.15


if __name__ == "__main__":
    main()
